//! Property-based tests (hand-rolled generators — proptest is unavailable
//! offline): randomized inputs over many iterations, asserting invariants
//! of the kernel library and the coordinator state machines.

use bitnet::coordinator::kv_pool::{KvArena, KvDtype};
use bitnet::coordinator::scheduler::{Phase, Scheduler, SeqState};
use bitnet::kernels::quant::{quantize_act_int8, training_scheme_ref_row, TernaryWeights};
use bitnet::kernels::sparse::{self, SparseMode};
use bitnet::kernels::{
    kernel_for, matmul_prepared, simd, Kernel, PreparedActivations, QTensor, QuantType, SimdLevel,
};
use bitnet::threadpool::ThreadPool;
use bitnet::util::Rng;

fn random_ternary(rng: &mut Rng, m: usize, k: usize) -> TernaryWeights {
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    // Snap the scale to an f16-representable value: the llama.cpp block
    // formats (and the F16 baseline) store scales in f16, so exact
    // round-trip properties only hold on the f16 grid - real BitNet
    // checkpoints are published the same way.
    let scale = bitnet::util::f16_to_f32(bitnet::util::f32_to_f16(0.02 + rng.next_f32() * 0.1));
    TernaryWeights::from_ternary(q, m, k, scale)
}

/// Invariant: pack → dequantize is exact for every ternary-native kernel,
/// across random shapes.
#[test]
fn prop_pack_roundtrip_all_shapes() {
    let mut rng = Rng::new(100);
    for trial in 0..40 {
        let m = 1 + rng.next_below(24);
        let k = 256 * (1 + rng.next_below(6));
        let t = random_ternary(&mut rng, m, k);
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            let info = kern.info();
            if !info.ternary_native || k % info.k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            assert_eq!(kern.dequantize(&packed), t.dequantize(), "{} trial {trial}", info.name);
        }
    }
}

/// Invariant: GEMV is linear in the weight scale.
#[test]
fn prop_gemv_scale_linearity() {
    let mut rng = Rng::new(200);
    for _ in 0..10 {
        let (m, k) = (8, 512);
        let mut t = random_ternary(&mut rng, m, k);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        for qt in [QuantType::I2S, QuantType::Tl21, QuantType::Tl11] {
            let kern = kernel_for(qt);
            t.scale = 1.0;
            let p1 = kern.quantize(&t);
            t.scale = 3.0;
            let p3 = kern.quantize(&t);
            let prep = kern.prepare(&x, k);
            let (mut o1, mut o3) = (vec![0f32; m], vec![0f32; m]);
            kern.gemv(&p1, &prep, &mut o1);
            kern.gemv(&p3, &prep, &mut o3);
            for r in 0..m {
                assert!((o3[r] - 3.0 * o1[r]).abs() <= 1e-4 * o1[r].abs().max(1.0), "{qt:?}");
            }
        }
    }
}

/// Invariant: GEMV distributes over weight-row sign flips:
/// negating every weight in a row negates the output exactly.
#[test]
fn prop_sign_flip_negates() {
    let mut rng = Rng::new(300);
    let (m, k) = (4, 768);
    for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21, QuantType::Tmac] {
        let t = random_ternary(&mut rng, m, k);
        let flipped = TernaryWeights::from_ternary(
            t.q.iter().map(|&v| -v).collect(),
            m,
            k,
            t.scale,
        );
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let kern = kernel_for(qt);
        let (pa, pb) = (kern.quantize(&t), kern.quantize(&flipped));
        let prep = kern.prepare(&x, k);
        let (mut oa, mut ob) = (vec![0f32; m], vec![0f32; m]);
        kern.gemv(&pa, &prep, &mut oa);
        kern.gemv(&pb, &prep, &mut ob);
        for r in 0..m {
            // For the integer-exact kernels this must hold bitwise; TMAC
            // requantizes tables so allow its block-scale noise.
            let tol = if kern.info().lossless { 0.0 } else { 0.1f32.max(0.05 * oa[r].abs()) };
            assert!((oa[r] + ob[r]).abs() <= tol, "{qt:?} row {r}: {} vs {}", oa[r], ob[r]);
        }
    }
}

/// Invariant: every kernel computes bit-identical results at every SIMD
/// tier this host offers, across random shapes, weights, activations,
/// and batch widths — the scalar path is the executable specification
/// and the vector paths may not diverge from it by a single bit.
#[test]
fn prop_scalar_simd_equivalence_random_shapes() {
    let mut rng = Rng::new(800);
    let pool = ThreadPool::new(2);
    let levels = simd::available_levels();
    for trial in 0..12 {
        let m = 1 + rng.next_below(40);
        let n = 1 + rng.next_below(6);
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            // `.max(4)` keeps K sane for the k_multiple = 1 baselines
            // while staying aligned for everyone (4, 8, 16, 128, 256
            // all divide their own max(4, ·)).
            let kmul = kern.info().k_multiple.max(4);
            let k = kmul * (1 + rng.next_below(24));
            let t = random_ternary(&mut rng, m, k);
            let packed = kern.quantize(&t);
            let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            let run = |level: SimdLevel| {
                simd::with_level(level, || {
                    let mut acts = PreparedActivations::new();
                    acts.begin_input();
                    let mut out = vec![0f32; n * m];
                    let batch = acts.get_or_prepare(kern, &x, k, n, &pool);
                    matmul_prepared(kern, &packed, batch, &x, n, &mut out, &pool);
                    out
                })
            };
            let reference = run(SimdLevel::Scalar);
            for &level in &levels {
                assert_eq!(
                    run(level),
                    reference,
                    "{qt:?} trial {trial} ({m},{k},{n}) at {}",
                    level.name()
                );
            }
        }
    }
}

/// Invariant: the lossless kernels stay bit-exact against the integer
/// training-scheme reference *through every vector path*, across random
/// shapes — SIMD LUT gathers and maddubs-style accumulation must
/// reproduce the exact blockwise integer sums, not just approximate
/// them.
#[test]
fn prop_lossless_exact_through_vector_paths() {
    let mut rng = Rng::new(900);
    let levels = simd::available_levels();
    for trial in 0..10 {
        let m = 1 + rng.next_below(16);
        for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
            let kern = kernel_for(qt);
            let k = kern.info().k_multiple.max(4) * (1 + rng.next_below(12));
            let t = random_ternary(&mut rng, m, k);
            let packed = kern.quantize(&t);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let act = quantize_act_int8(&x);
            for &level in &levels {
                let out = simd::with_level(level, || {
                    let p = kern.prepare(&x, k);
                    let mut out = vec![0f32; m];
                    kern.gemv(&packed, &p, &mut out);
                    out
                });
                for r in 0..m {
                    assert_eq!(
                        out[r],
                        training_scheme_ref_row(t.row(r), t.scale, &act),
                        "{qt:?} trial {trial} row {r} at {}",
                        level.name()
                    );
                }
            }
        }
    }
}

/// Batched matmul through the prepare-once path under a forced SIMD
/// tier (the sparse invariants' shared runner).
fn run_prepared(
    kern: &'static dyn Kernel,
    packed: &QTensor,
    x: &[f32],
    (m, k, n): (usize, usize, usize),
    pool: &ThreadPool,
    level: SimdLevel,
) -> Vec<f32> {
    simd::with_level(level, || {
        let mut acts = PreparedActivations::new();
        acts.begin_input();
        let mut out = vec![0f32; n * m];
        let batch = acts.get_or_prepare(kern, x, k, n, pool);
        matmul_prepared(kern, packed, batch, x, n, &mut out, pool);
        out
    })
}

/// Invariant: the block-skip layout never changes a single output bit —
/// sparse ≡ dense ≡ scalar across random block-zero patterns, shapes,
/// batch widths, kernels, and SIMD tiers. Zeros come in 384-column
/// stripes (a common multiple of every sparse kernel's block span: 64
/// for TL1/ELUT, 128 for I2_S, 96 for TL2's trio region), the same
/// columns in every row, so whole blocks actually elide in the vector
/// tile paths too.
#[test]
fn prop_sparse_dense_equivalence_random_patterns() {
    let mut rng = Rng::new(1000);
    let pool = ThreadPool::new(2);
    let levels = simd::available_levels();
    for trial in 0..8 {
        let m = 1 + rng.next_below(40);
        let n = 1 + rng.next_below(4);
        let stripes = 2 + rng.next_below(4);
        let k = 384 * stripes;
        let zero: Vec<bool> = (0..stripes).map(|_| rng.next_f32() < 0.6).collect();
        let q: Vec<i8> = (0..m * k)
            .map(|i| if zero[(i % k) / 384] { 0 } else { rng.next_ternary() as i8 })
            .collect();
        let t = TernaryWeights::from_ternary(q, m, k, 0.05);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if !kern.sparse_capable() {
                continue;
            }
            let dense = sparse::with_mode(SparseMode::Off, || kern.quantize(&t));
            let sp = sparse::with_mode(SparseMode::On, || kern.quantize(&t));
            assert!(sp.sparse.is_some(), "{qt:?} trial {trial}: forced-on must attach the index");
            let reference = run_prepared(kern, &dense, &x, (m, k, n), &pool, SimdLevel::Scalar);
            for &level in &levels {
                assert_eq!(
                    run_prepared(kern, &dense, &x, (m, k, n), &pool, level),
                    reference,
                    "{qt:?} trial {trial} ({m},{k},{n}) dense at {}",
                    level.name()
                );
                assert_eq!(
                    run_prepared(kern, &sp, &x, (m, k, n), &pool, level),
                    reference,
                    "{qt:?} trial {trial} ({m},{k},{n}) at {}: sparse ≡ dense ≡ scalar",
                    level.name()
                );
            }
        }
    }
}

/// Degenerate sparsity invariants: an all-zero tensor (every block
/// elides), a zero-free tensor (nothing elides, `Auto` keeps it dense),
/// and a single nonzero weight per 384-column stripe (almost every
/// block elides; each surviving block holds exactly one nonzero). In
/// every case the packed bytes dequantize exactly through *both*
/// layouts and gemv stays bit-identical to the dense scalar reference
/// at every tier.
#[test]
fn prop_degenerate_sparsity_layouts() {
    let mut rng = Rng::new(1100);
    let (m, k) = (9usize, 1152usize); // 3 stripes of 384
    let stripes = k / 384;
    let scale = bitnet::util::f16_to_f32(bitnet::util::f32_to_f16(0.05));
    for trial in 0..4 {
        // One nonzero column per stripe, shared by every row.
        let cols: Vec<usize> =
            (0..stripes).map(|s| s * 384 + rng.next_below(384)).collect();
        let single: Vec<i8> = (0..m * k)
            .map(|i| if cols.contains(&(i % k)) { 1 - 2 * ((i / k) % 2) as i8 } else { 0 })
            .collect();
        let zero_free: Vec<i8> =
            (0..m * k).map(|_| if rng.next_f32() < 0.5 { 1 } else { -1 }).collect();
        let cases: [(&str, Vec<i8>); 3] = [
            ("all-zero", vec![0i8; m * k]),
            ("zero-free", zero_free),
            ("single-per-stripe", single),
        ];
        for (label, q) in cases {
            let t = TernaryWeights::from_ternary(q, m, k, scale);
            let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            for qt in QuantType::ALL {
                let kern = kernel_for(qt);
                if !kern.sparse_capable() {
                    continue;
                }
                let dense = sparse::with_mode(SparseMode::Off, || kern.quantize(&t));
                let sp = sparse::with_mode(SparseMode::On, || kern.quantize(&t));
                let auto = sparse::with_mode(SparseMode::Auto, || kern.quantize(&t));
                // The index is additive: both layouts dequantize exactly.
                let want = t.dequantize();
                assert_eq!(kern.dequantize(&dense), want, "{qt:?} {label} trial {trial}");
                assert_eq!(kern.dequantize(&sp), want, "{qt:?} {label} trial {trial} (sparse)");
                let idx = sp.sparse.as_ref().expect("forced-on must attach the index");
                match label {
                    "all-zero" => {
                        assert_eq!(idx.nonzero_blocks(), 0, "{qt:?}");
                        assert!((idx.zero_block_fraction() - 1.0).abs() < 1e-12, "{qt:?}");
                        assert!(auto.sparse.is_some(), "{qt:?}: all-zero clears any threshold");
                    }
                    "zero-free" => {
                        assert_eq!(idx.nonzero_blocks(), idx.total_blocks(), "{qt:?}");
                        assert!(auto.sparse.is_none(), "{qt:?}: zero-free must stay dense");
                    }
                    _ => {
                        // Each lone nonzero lands in exactly one block.
                        assert_eq!(idx.nonzero_blocks(), m * stripes, "{qt:?}");
                    }
                }
                let reference = simd::with_level(SimdLevel::Scalar, || {
                    let p = kern.prepare(&x, k);
                    let mut out = vec![0f32; m];
                    kern.gemv(&dense, &p, &mut out);
                    out
                });
                for &level in &simd::available_levels() {
                    let out = simd::with_level(level, || {
                        let p = kern.prepare(&x, k);
                        let mut out = vec![0f32; m];
                        kern.gemv(&sp, &p, &mut out);
                        out
                    });
                    assert_eq!(
                        out,
                        reference,
                        "{qt:?} {label} trial {trial} at {}",
                        level.name()
                    );
                }
            }
        }
    }
}

/// KvArena invariant: pages are conserved under random reserve/release.
#[test]
fn prop_kv_pool_page_conservation() {
    let mut rng = Rng::new(400);
    for _ in 0..20 {
        let total_pages = 8 + rng.next_below(64);
        let mut pool = KvArena::accounting(total_pages * 16);
        let mut active: Vec<u64> = Vec::new();
        for step in 0..200u64 {
            if rng.next_f32() < 0.6 {
                let tokens = 1 + rng.next_below(total_pages * 16);
                if pool.reserve(step, tokens) {
                    active.push(step);
                }
            } else if let Some(pos) = (!active.is_empty()).then(|| rng.next_below(active.len())) {
                let id = active.swap_remove(pos);
                pool.release(id);
            }
            let held: usize = active.iter().map(|&id| pool.held_pages(id)).sum();
            assert_eq!(held + pool.free_page_count(), pool.total_pages(), "conservation");
        }
    }
}

/// Scheduler invariant under watermark admission: running set never
/// exceeds max_batch; sequences grow page-by-page as they decode (the
/// driver mirrors the engine's on_prefilled notifications so growth and
/// LIFO preemption actually engage); all accepted sequences eventually
/// complete and every page is released.
#[test]
fn prop_scheduler_liveness_and_caps() {
    let mut rng = Rng::new(500);
    for trial in 0..15 {
        let max_batch = 1 + rng.next_below(6);
        let mut pool = KvArena::accounting(16 * (16 + rng.next_below(64)));
        let mut sch = Scheduler::new(max_batch);
        let n_reqs = 10 + rng.next_below(20);
        let mut accepted = 0usize;
        for id in 0..n_reqs as u64 {
            let prompt = 1 + rng.next_below(40);
            let max_new = 1 + rng.next_below(30);
            let seq = SeqState { id, prompt_len: prompt, max_new_tokens: max_new, generated: 0, phase: Phase::Waiting };
            if sch.submit(seq, &pool) {
                accepted += 1;
            }
        }
        let mut completed = 0usize;
        let mut remaining: std::collections::HashMap<u64, usize> = Default::default();
        for _ in 0..10_000 {
            let plan = sch.step(&mut pool);
            if plan.decode.is_empty() {
                break;
            }
            assert!(plan.decode.len() <= max_batch, "trial {trial}");
            // Mirror the engine: admitted (or re-admitted) prompts are
            // prefilled this step, flipping Prefill → Decoding so the
            // next step's growth reservations run for them.
            for id in &plan.prefill {
                sch.on_prefilled(*id);
            }
            for id in plan.decode.clone() {
                let left = remaining.entry(id).or_insert_with(|| 1 + rng.next_below(30));
                sch.on_token(id);
                *left -= 1;
                if *left == 0 {
                    sch.finish(id, &mut pool);
                    completed += 1;
                }
            }
        }
        assert_eq!(completed, accepted, "all accepted sequences complete (trial {trial})");
        assert_eq!(pool.used_pages(), 0, "all pages released (trial {trial})");
    }
    // (Deterministic preemption coverage lives in the scheduler's own
    // preemption_never_deadlocks test; these random trials may or may
    // not hit memory pressure depending on the draw.)
}

/// Tokenizer invariant: encode→decode identity over random byte soup.
#[test]
fn prop_tokenizer_roundtrip_fuzz() {
    use bitnet::tokenizer::{synthetic_corpus, Tokenizer};
    let tok = Tokenizer::train(&synthetic_corpus(3000, 8), 512);
    let mut rng = Rng::new(600);
    for _ in 0..50 {
        let len = rng.next_below(120);
        let s: String = (0..len)
            .map(|_| char::from_u32(32 + rng.next_below(95) as u32).unwrap())
            .collect();
        assert_eq!(tok.decode(&tok.encode(&s)), s);
    }
}

/// f16 round-trip invariant on random finite floats within half range.
#[test]
fn prop_f16_monotone_and_bounded() {
    use bitnet::util::{f16_to_f32, f32_to_f16};
    let mut rng = Rng::new(700);
    for _ in 0..10_000 {
        let v = (rng.next_f32_signed()) * 60000.0;
        let rt = f16_to_f32(f32_to_f16(v));
        let ulp = (v.abs() / 1024.0).max(6e-8); // half has 10 mantissa bits
        assert!((rt - v).abs() <= ulp, "{v} -> {rt}");
    }
}

/// Invariant: the paged fused attend is bit-identical between the
/// forced-scalar tier and every vector tier this host offers, across
/// random GQA geometry (incl. MQA), head dims with remainder tails,
/// context lengths, page sizes, and both KV dtypes (f16 decodes inside
/// the vector loops).
#[test]
fn prop_attend_scalar_simd_equivalence_random_geometry() {
    let mut rng = Rng::new(1200);
    let levels = simd::available_levels();
    for trial in 0..25 {
        let head_dim = 2 * (1 + rng.next_below(12));
        let n_kv_heads = 1 + rng.next_below(4);
        let group = 1 + rng.next_below(3);
        let n_heads = n_kv_heads * group;
        let kv_dim = n_kv_heads * head_dim;
        let ctx = 1 + rng.next_below(40);
        let page_tokens = [1usize, 2, 3, 5, 8, 16, 64][rng.next_below(7)];
        let dtype = if rng.next_below(2) == 0 { KvDtype::F32 } else { KvDtype::F16 };
        let mut arena = KvArena::with_page_tokens(1, kv_dim, 8192, dtype, page_tokens);
        assert!(arena.reserve(1, ctx));
        for pos in 0..ctx {
            let k: Vec<f32> = (0..kv_dim).map(|_| rng.next_gaussian()).collect();
            let v: Vec<f32> = (0..kv_dim).map(|_| rng.next_gaussian()).collect();
            arena.append(1, 0, pos, &k, &v);
        }
        let q: Vec<f32> = (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
        let scale = 1.0 / (head_dim as f32).sqrt();
        let attend_at = |level: SimdLevel| {
            simd::with_level(level, || {
                let mut out = vec![0f32; n_heads * head_dim];
                arena.attend(1, 0, &q, ctx, n_heads, n_kv_heads, head_dim, scale, &mut out);
                out
            })
        };
        let reference = attend_at(SimdLevel::Scalar);
        assert!(reference.iter().all(|v| v.is_finite()), "trial {trial}");
        for &level in &levels {
            assert_eq!(
                attend_at(level),
                reference,
                "trial {trial} ({n_heads}h/{n_kv_heads}kv hd={head_dim} ctx={ctx} \
                 page={page_tokens} {dtype:?}) at {}",
                level.name()
            );
        }
    }
}

/// Invariant: attention over an all-shared copy-on-write page table
/// (prefix registered by one sequence, mapped by another) reads the
/// exact same bits as the owning sequence, at every SIMD tier. Shared
/// pages are pure page-table indirection — sharing must be invisible to
/// the math.
#[test]
fn prop_attend_on_shared_cow_pages_identical_across_levels() {
    let mut rng = Rng::new(1300);
    let levels = simd::available_levels();
    for trial in 0..10 {
        let head_dim = 2 * (1 + rng.next_below(8));
        let n_kv_heads = 1 + rng.next_below(3);
        let group = 1 + rng.next_below(3);
        let n_heads = n_kv_heads * group;
        let kv_dim = n_kv_heads * head_dim;
        let page_tokens = [2usize, 4, 8, 16][rng.next_below(4)];
        let full_pages = 2 + rng.next_below(3);
        // A strictly partial tail keeps the last page private (a full
        // tail page would itself be indexed and shared); the full pages
        // are the shared prefix.
        let ctx = full_pages * page_tokens + 1 + rng.next_below(page_tokens - 1);
        let dtype = if trial % 2 == 0 { KvDtype::F32 } else { KvDtype::F16 };
        let mut arena = KvArena::with_page_tokens(1, kv_dim, 8192, dtype, page_tokens);
        let prompt: Vec<u32> = (0..ctx as u32).map(|i| 3 + (i * 7) % 90).collect();
        assert!(arena.reserve(1, ctx));
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..ctx)
            .map(|_| {
                (
                    (0..kv_dim).map(|_| rng.next_gaussian()).collect(),
                    (0..kv_dim).map(|_| rng.next_gaussian()).collect(),
                )
            })
            .collect();
        for (pos, (k, v)) in rows.iter().enumerate() {
            arena.append(1, 0, pos, k, v);
        }
        arena.register_prefix(1, &prompt);
        let resident = arena.map_prefix(2, &prompt);
        assert_eq!(resident, full_pages * page_tokens, "trial {trial}: full pages map");
        assert!(arena.reserve(2, ctx));
        for pos in resident..ctx {
            arena.append(2, 0, pos, &rows[pos].0, &rows[pos].1);
        }
        let q: Vec<f32> = (0..n_heads * head_dim).map(|_| rng.next_gaussian()).collect();
        let scale = 1.0 / (head_dim as f32).sqrt();
        let attend_at = |seq: u64, level: SimdLevel| {
            simd::with_level(level, || {
                let mut out = vec![0f32; n_heads * head_dim];
                arena.attend(seq, 0, &q, ctx, n_heads, n_kv_heads, head_dim, scale, &mut out);
                out
            })
        };
        let reference = attend_at(1, SimdLevel::Scalar);
        for &level in &levels {
            assert_eq!(attend_at(1, level), reference, "trial {trial} owner at {}", level.name());
            assert_eq!(
                attend_at(2, level),
                reference,
                "trial {trial} at {}: shared COW pages must read identically",
                level.name()
            );
        }
    }
}

/// Invariant: every attention/ops SIMD primitive is bit-identical to the
/// forced-scalar tier at random lengths (sub-register slices, exact
/// multiples, and remainder tails all arise by construction).
#[test]
fn prop_ops_scalar_simd_equivalence_random_lengths() {
    use bitnet::simd::ops;
    let mut rng = Rng::new(1400);
    let levels = simd::available_levels();
    for trial in 0..40 {
        let n = 1 + rng.next_below(300);
        let a: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let h: Vec<u16> = b.iter().map(|&v| bitnet::util::f32_to_f16(v)).collect();
        let gain: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let pairs = 1 + rng.next_below(80);
        let rot0: Vec<f32> = (0..2 * pairs).map(|_| rng.next_gaussian()).collect();
        let angles: Vec<f32> = (0..pairs).map(|i| 0.3 * i as f32 + trial as f32).collect();
        let sin: Vec<f32> = angles.iter().map(|v| v.sin()).collect();
        let cos: Vec<f32> = angles.iter().map(|v| v.cos()).collect();
        let eval = |level: SimdLevel| {
            simd::with_level(level, || {
                let mut y = b.clone();
                ops::axpy_f32(0.37, &a, &mut y);
                let mut y16 = a.clone();
                ops::axpy_f16(-1.25, &h, &mut y16);
                let mut sg = vec![0f32; n];
                ops::scale_gain(&a, 0.8, &gain, &mut sg);
                let mut sm = a.clone();
                bitnet::util::softmax(&mut sm);
                let mut sl = vec![0f32; n];
                ops::silu_mul(&a, &b, &mut sl);
                let mut rot = rot0.clone();
                ops::rope_rotate(&mut rot, &sin, &cos);
                (
                    (
                        ops::dot_f32(&a, &b),
                        ops::dot_f16(&a, &h),
                        ops::sum_squares(&a),
                        ops::sum(&a),
                        ops::max_val(&a),
                    ),
                    (y, y16, sg, sm, sl, rot),
                )
            })
        };
        let reference = eval(SimdLevel::Scalar);
        for &level in &levels {
            assert_eq!(eval(level), reference, "trial {trial} n={n} at {}", level.name());
        }
    }
}
