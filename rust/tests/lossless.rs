//! The paper's lossless claim (Figure 2, Table 2), asserted end-to-end:
//! kernels marked `lossless` must reproduce the BitNet b1.58
//! training-scheme computation bit-for-bit, at the GEMV level, the model
//! logits level, and the perplexity level; non-lossless kernels must NOT
//! (otherwise the table's distinction would be vacuous).

use bitnet::eval::{eval_token_stream, perplexity};
use bitnet::kernels::quant::{quantize_act_int8, training_scheme_ref_row, TernaryWeights};
use bitnet::kernels::{kernel_for, QuantType};
use bitnet::model::{ModelConfig, Transformer};
use bitnet::util::Rng;

fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
    let mut rng = Rng::new(seed);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    TernaryWeights::from_ternary(q, m, k, 0.031)
}

#[test]
fn lossless_kernels_match_training_scheme_gemv() {
    let (m, k) = (32, 1024);
    let t = random_ternary(m, k, 1);
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
    let act = quantize_act_int8(&x);
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let info = kern.info();
        if !info.lossless || k % info.k_multiple != 0 {
            continue;
        }
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, k);
        let mut out = vec![0f32; m];
        kern.gemv(&packed, &p, &mut out);
        for r in 0..m {
            assert_eq!(
                out[r],
                training_scheme_ref_row(t.row(r), t.scale, &act),
                "{} row {r}",
                info.name
            );
        }
    }
}

#[test]
fn non_lossless_kernels_deviate_somewhere() {
    // Activations with block-varying dynamic range expose per-block
    // quantization; LUT requantization exposes the _0 kernels.
    let (m, k) = (32, 1024);
    let t = random_ternary(m, k, 3);
    let mut rng = Rng::new(4);
    let mut x: Vec<f32> = (0..k).map(|_| rng.next_gaussian() * 0.05).collect();
    x[5] = 6.0;
    let act = quantize_act_int8(&x);
    for qt in [QuantType::Tq10, QuantType::Tq20, QuantType::Tl10, QuantType::Tl20, QuantType::Tmac]
    {
        let kern = kernel_for(qt);
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, k);
        let mut out = vec![0f32; m];
        kern.gemv(&packed, &p, &mut out);
        let any_diff =
            (0..m).any(|r| out[r] != training_scheme_ref_row(t.row(r), t.scale, &act));
        assert!(any_diff, "{} unexpectedly bit-exact", kern.info().name);
    }
}

#[test]
fn lossless_logits_identical_across_kernels() {
    let cfg = ModelConfig::tiny();
    let tokens = [7u32, 77, 300, 4, 18, 255];
    let reference: Vec<f32> = {
        let model = Transformer::synthetic(&cfg, QuantType::I2S, 99);
        let mut s = model.new_session(32);
        model.prefill(&mut s, &tokens)
    };
    for qt in [QuantType::Tl11, QuantType::Tl21, QuantType::Elut4, QuantType::Elut5] {
        let model = Transformer::synthetic(&cfg, qt, 99);
        let mut s = model.new_session(32);
        let logits = model.prefill(&mut s, &tokens);
        assert_eq!(logits, reference, "{qt:?} logits must be bit-identical to I2_S");
    }
}

/// Paper Table 2 (synthetic stand-in): lossless kernels → identical
/// perplexity; fast `_0` kernels → negligible delta; Q4_0 → small but
/// visible delta. The *ordering* of the paper's table is preserved.
#[test]
fn table2_perplexity_structure() {
    let cfg = ModelConfig::tiny();
    let tokens = eval_token_stream(cfg.vocab_size, 48, 10);
    let ppl = |qt: QuantType| {
        let model = Transformer::synthetic(&cfg, qt, 123);
        perplexity(&model, &tokens)
    };
    let p_ref = ppl(QuantType::I2S);
    assert_eq!(ppl(QuantType::Tl11), p_ref);
    assert_eq!(ppl(QuantType::Tl21), p_ref);
    for qt in [QuantType::Tl10, QuantType::Tl20, QuantType::Tq10, QuantType::Tq20] {
        let p = ppl(qt);
        assert!((p - p_ref).abs() / p_ref < 0.05, "{qt:?}: {p} vs {p_ref}");
    }
    let p_q4 = ppl(QuantType::Q40);
    assert!((p_q4 - p_ref).abs() / p_ref < 0.5, "Q4_0 within the ballpark: {p_q4} vs {p_ref}");
}
