//! Pin down the `bitnet` facade's public surface after the workspace
//! split: every pre-split path must keep resolving and composing, so
//! downstream code (and the other tests in this directory) never learn
//! which of the four layered crates an item landed in. Each assertion
//! here is a path that existed before the split — if a re-export is
//! dropped or renamed, this file stops compiling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Type-position pins: referencing the path is the assertion.
#[allow(dead_code, clippy::too_many_arguments)]
fn type_pins(
    _: &bitnet::model::Session,
    _: &bitnet::model::Transformer,
    _: &bitnet::model::ModelConfig,
    _: &bitnet::model::weights::Checkpoint,
    _: &dyn bitnet::kernels::Kernel,
    _: bitnet::kernels::QuantType,
    _: &bitnet::coordinator::Engine,
    _: &bitnet::coordinator::EngineConfig,
    _: &bitnet::coordinator::kv_pool::KvArena,
    _: bitnet::coordinator::KvDtype,
    _: &bitnet::coordinator::Request,
    _: &bitnet::coordinator::ServingTrace,
    _: &bitnet::threadpool::ThreadPool,
    _: &bitnet::topology::Topology,
    _: &bitnet::metrics::EngineMetrics,
    _: &bitnet::TuningProfile,
    _: bitnet::Role,
    _: &bitnet::kernels::tuner::OverrideSearchConfig,
) {
}

#[test]
fn facade_fn_items_resolve() {
    // Value-position pins: fn items through their historical paths. The
    // tuner graft splices `pallas_model::tuner_e2e` back under
    // `kernels::tuner`, and `perf::calibrate` regains the model-level
    // throughput estimate — both must sit beside the kernels-crate half.
    let _ = bitnet::kernels::tuner::tune;
    let _ = bitnet::kernels::tuner::measure_e2e;
    let _ = bitnet::kernels::tuner::measure_dispatch_e2e;
    let _ = bitnet::kernels::tuner::search_overrides;
    let _ = bitnet::kernels::tuner::shapes_for_model;
    let _ = bitnet::perf::calibrate::tokens_per_second;
    let _ = bitnet::kernels::kernel_for;
    let _ = bitnet::kernels::library_table;
    let _ = bitnet::kernels::simd::active_level;
    let _ = bitnet::kernels::sparse::mode;
    let _ = bitnet::coordinator::Engine::start;
    let _ = bitnet::tokenizer::Tokenizer::train;
    let _ = bitnet::modelio::load;
    let _ = bitnet::util::Rng::new;
    let _ = bitnet::topology::set_mode;
    let _ = bitnet::threadpool::shared_pool;
    let _: bitnet::Result<()> = Ok(());
}

#[test]
fn facade_paths_compose_end_to_end() {
    // The quick-start composition from the crate docs, spelled entirely
    // in facade paths.
    let cfg = bitnet::model::ModelConfig::tiny();
    let model = bitnet::model::Transformer::synthetic(&cfg, bitnet::QuantType::I2S, 7);
    let mut session: bitnet::model::Session = model.new_session(16);
    let logits = model.prefill(&mut session, &[1, 2, 3]);
    assert_eq!(logits.len(), cfg.vocab_size);
    drop(session);

    // The kernel library behind the trait object it always exposed.
    let k: &'static dyn bitnet::kernels::Kernel =
        bitnet::kernels::kernel_for(bitnet::kernels::QuantType::I2S);
    assert!(k.info().k_multiple >= 1);

    // kv_pool is the arena re-layered into pallas-core, re-exported at
    // its pre-split coordinator path; sharing idiom unchanged.
    let arena = bitnet::coordinator::kv_pool::KvArena::new(
        1,
        8,
        4 * bitnet::coordinator::PAGE_TOKENS,
        bitnet::coordinator::KvDtype::F32,
    );
    assert!(arena.total_pages() > 0);
    let _shared: Arc<Mutex<bitnet::coordinator::KvArena>> = Arc::new(Mutex::new(arena));

    // The engine consumes the model exactly as before the split.
    let engine =
        bitnet::coordinator::Engine::start(model, bitnet::coordinator::EngineConfig::default());
    let (tokens, reason, _) =
        engine.submit(bitnet::coordinator::Request::greedy(vec![4, 5], 2)).wait();
    assert_eq!(tokens.len(), 2);
    assert_eq!(reason, bitnet::coordinator::FinishReason::Length);

    // Thread pool and topology at the facade root.
    let pool = bitnet::threadpool::ThreadPool::new(2);
    let sum = AtomicUsize::new(0);
    pool.parallel_for(8, |i| {
        sum.fetch_add(i, Ordering::Relaxed);
    });
    assert_eq!(sum.load(Ordering::Relaxed), 28);
    assert_eq!(bitnet::topology::Topology::mock(2).n_nodes(), 2);
}
