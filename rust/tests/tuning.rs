//! Auto-tuned dispatch integration tests: profile round-trip through disk
//! (save → load → identical dispatch decisions), the correctness smoke
//! test that `Auto` dispatch is bit-identical to `Fixed` for the lossless
//! kernels (TL1_1, TL2_1, I2_S), and the phase-aware multi-packed path:
//! distinct prefill (n>1) and decode (n=1) winners routing one BitLinear
//! through different kernels per phase, per-layer overrides, v1 profile
//! migration, and fallback accounting.

use bitnet::coordinator::ServingTrace;
use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::tuner::{
    measure_e2e, search_overrides, tune, LayerOverride, Measurement, OverrideSearchConfig, Role,
    TuneConfig, TuningEntry,
};
use bitnet::kernels::{kernel_for, Dispatch, QuantType, SimdLevel, TuningProfile};
use bitnet::model::weights::Checkpoint;
use bitnet::model::{BitLinear, ModelConfig, Transformer};
use bitnet::threadpool::ThreadPool;
use bitnet::util::Rng;

fn entry(m: usize, k: usize, n: usize, best: QuantType) -> TuningEntry {
    TuningEntry {
        m,
        k,
        n,
        weight: 1.0,
        best,
        best_simd: SimdLevel::Scalar,
        best_sparse: false,
        measurements: vec![Measurement {
            qtype: best,
            simd: SimdLevel::Scalar,
            sparse: false,
            us_per_matmul: 10.0,
            gweights_per_s: (m * k) as f64 / 10.0e-6 / 1e9,
        }],
    }
}

/// A hand-built profile covering every projection shape of the tiny
/// preset, pinning each to a chosen lossless kernel.
fn tiny_profile(best_for_all: QuantType) -> TuningProfile {
    let cfg = ModelConfig::tiny();
    let mut p = TuningProfile::empty(QuantType::I2S, 1);
    for (m, k) in bitnet::kernels::tuner::shapes_for_model(&cfg) {
        p.entries.push(entry(m, k, 1, best_for_all));
    }
    p
}

#[test]
fn profile_round_trip_preserves_dispatch_decisions() {
    let cfg = ModelConfig::tiny();
    let shapes = bitnet::kernels::tuner::shapes_for_model(&cfg);
    let mut profile = TuningProfile::empty(QuantType::I2S, 2);
    // Mix of winners across shapes and batches.
    let kinds = [QuantType::Tl20, QuantType::Tl11, QuantType::Tq20, QuantType::I2S];
    for (i, &(m, k)) in shapes.iter().enumerate() {
        profile.entries.push(entry(m, k, 1, kinds[i % kinds.len()]));
        profile.entries.push(entry(m, k, 4, kinds[(i + 1) % kinds.len()]));
    }

    let dir = std::env::temp_dir().join("bitnet_tuning_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("profile.json");
    profile.save(&path).unwrap();
    let loaded = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    assert_eq!(loaded, profile, "profile must round-trip losslessly");
    // The contract that matters: identical selections for every shape at
    // every batch size, including fallback shapes missing from the profile.
    for &(m, k) in &shapes {
        for n in [1usize, 2, 4, 8, 64] {
            assert_eq!(loaded.select(m, k, n), profile.select(m, k, n), "{m}x{k} n={n}");
        }
    }
    assert_eq!(loaded.select(12345, 678, 1), profile.select(12345, 678, 1));
}

#[test]
fn auto_dispatch_is_bit_identical_to_fixed_for_lossless_kernels() {
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 99);
    let tokens = [3u32, 1, 4, 1, 5, 9, 2, 6];
    for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
        let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(qt), 1);
        let auto =
            Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(tiny_profile(qt)), 1);
        assert_eq!(auto.qtype, qt, "representative kernel under auto");
        let mut s1 = fixed.new_session(32);
        let mut s2 = auto.new_session(32);
        let l1 = fixed.prefill(&mut s1, &tokens);
        let l2 = auto.prefill(&mut s2, &tokens);
        assert_eq!(l1, l2, "{qt:?}: auto vs fixed logits must be bit-identical");
    }
}

#[test]
fn auto_dispatch_mixing_lossless_kernels_matches_fixed_i2s() {
    // Different lossless kernels per shape still produce the exact I2_S
    // logits — the model-level Figure-2 property, now via dispatch.
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 7);
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    let lossless = [QuantType::I2S, QuantType::Tl11, QuantType::Tl21];
    for (i, (m, k)) in bitnet::kernels::tuner::shapes_for_model(&cfg).into_iter().enumerate() {
        profile.entries.push(entry(m, k, 1, lossless[i % lossless.len()]));
    }
    let auto = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(profile), 1);
    // The mix really is a mix.
    let kernels: std::collections::HashSet<_> =
        auto.kernel_summary().into_iter().map(|(_, _, q)| q).collect();
    assert!(kernels.len() > 1, "expected heterogeneous dispatch, got {kernels:?}");

    let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(QuantType::I2S), 1);
    let tokens = [5u32, 10, 400, 3, 77];
    let mut s1 = fixed.new_session(32);
    let mut s2 = auto.new_session(32);
    assert_eq!(fixed.prefill(&mut s1, &tokens), auto.prefill(&mut s2, &tokens));
}

#[test]
fn forward_batch_n1_is_bit_identical_to_forward_for_every_kernel() {
    // The phase-aware router treats n=1 as "decode" and n>1 as "prefill/
    // batched"; the two code paths must agree exactly at the boundary.
    let (m, k) = (32, 768);
    let mut rng = Rng::new(21);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let w = TernaryWeights::from_ternary(q, m, k, 0.0625);
    let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
    let pool = ThreadPool::new(2);
    for qt in QuantType::ALL {
        if k % kernel_for(qt).info().k_multiple != 0 {
            continue;
        }
        let layer = BitLinear::new(&w, qt);
        let mut single = vec![0f32; m];
        layer.forward(&x, &mut single);
        let mut batched = vec![0f32; m];
        layer.forward_batch(&x, 1, &mut batched, &pool);
        assert_eq!(single, batched, "{qt:?}: forward vs forward_batch(n=1)");
        let mut routed = vec![0f32; m];
        let ran = layer.forward_batch_with(qt, &x, 1, &mut routed, &pool);
        assert_eq!(ran, qt, "{qt:?}: routed call must run the requested kernel");
        assert_eq!(single, routed, "{qt:?}: forward vs routed forward_batch_with(n=1)");
    }
}

#[test]
fn distinct_phase_winners_route_one_bitlinear_through_two_kernels_losslessly() {
    // The acceptance criterion: a profile whose decode (n=1) winner is
    // I2_S and whose prefill (n=8) winner is TL2_1 must run the SAME
    // BitLinear through both kernels across a prefill→decode run, with
    // logits bit-identical to the Fixed I2_S baseline (both lossless).
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 31);
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    for (m, k) in bitnet::kernels::tuner::shapes_for_model(&cfg) {
        profile.entries.push(entry(m, k, 1, QuantType::I2S));
        profile.entries.push(entry(m, k, 8, QuantType::Tl21));
    }
    let auto = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(profile), 1);
    let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(QuantType::I2S), 1);
    let tokens = [3u32, 1, 4, 1, 5, 9, 2, 6]; // 8-token chunk → the n=8 regime
    let mut sa = auto.new_session(32);
    let mut sf = fixed.new_session(32);
    let mut la = auto.prefill(&mut sa, &tokens);
    let mut lf = fixed.prefill(&mut sf, &tokens);
    assert_eq!(la, lf, "prefill logits must be bit-identical");
    for step in 0..4u32 {
        la = auto.decode_step(&mut sa, 7 + step);
        lf = fixed.decode_step(&mut sf, 7 + step);
        assert_eq!(la, lf, "decode step {step}");
    }
    // Every projection served decode on its I2_S primary and prefill on
    // a lazily packed TL2_1 alternate.
    for (li, layer) in auto.layers.iter().enumerate() {
        let packed = layer.wq.packed_kernels();
        assert_eq!(layer.wq.qtype(), QuantType::I2S, "layer {li} primary is the decode winner");
        assert!(
            packed.contains(&QuantType::Tl21),
            "layer {li} must have packed the prefill winner, got {packed:?}"
        );
    }
    // Memory cost of multi-packing is reported and bounded: resident
    // bytes exceed the per-token stream, but by at most the alternates.
    assert!(auto.resident_weight_bytes() > auto.weight_bytes_per_token());
    assert_eq!(
        fixed.resident_weight_bytes(),
        fixed.weight_bytes_per_token(),
        "fixed dispatch packs nothing extra"
    );
    assert_eq!(auto.plan.fallbacks(), 0, "profile covers every shape");
}

#[test]
fn per_layer_overrides_pin_layers_to_distinct_kernels() {
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 47);
    // Shape entries say I2_S everywhere; overrides pin layer 1's FFN to
    // TL1_1 at every batch width.
    let mut profile = tiny_profile(QuantType::I2S);
    for role in [Role::Gate, Role::Up, Role::Down] {
        profile.overrides.push(LayerOverride { layer: 1, role, n: 1, qtype: QuantType::Tl11 });
    }
    let auto = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(profile), 1);
    assert_eq!(auto.layers[1].w_gate.qtype(), QuantType::Tl11, "override applies");
    assert_eq!(auto.layers[0].w_gate.qtype(), QuantType::I2S, "other layers untouched");
    assert_eq!(auto.layers[1].wq.qtype(), QuantType::I2S, "other roles untouched");
    // All-lossless mix: logits stay bit-identical to fixed I2_S across a
    // prefill→decode run.
    let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(QuantType::I2S), 1);
    let tokens = [5u32, 10, 400, 3, 77];
    let mut sa = auto.new_session(32);
    let mut sf = fixed.new_session(32);
    assert_eq!(auto.prefill(&mut sa, &tokens), fixed.prefill(&mut sf, &tokens));
    assert_eq!(auto.decode_step(&mut sa, 9), fixed.decode_step(&mut sf, 9));
}

#[test]
fn incompatible_override_degrades_to_default_instead_of_panicking() {
    // K=384 fits I2_S (K % 128) but not TQ2_0 (K % 256): an override
    // naming TQ2_0 for the down projection must degrade to the profile
    // default at construction, not panic.
    let cfg = ModelConfig {
        name: "micro",
        hidden: 128,
        ffn: 384,
        n_layers: 1,
        n_heads: 2,
        n_kv_heads: 2,
        vocab_size: 64,
        max_seq_len: 32,
        rope_theta: 10000.0,
        rms_eps: 1e-5,
    };
    let ck = Checkpoint::synthetic(&cfg, 3);
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    profile.overrides.push(LayerOverride {
        layer: 0,
        role: Role::Down,
        n: 1,
        qtype: QuantType::Tq20,
    });
    let model = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(profile), 1);
    assert_eq!(model.layers[0].w_down.qtype(), QuantType::I2S, "degrade to profile default");
    let mut s = model.new_session(16);
    assert!(model.prefill(&mut s, &[1, 2, 3]).iter().all(|v| v.is_finite()));
}

#[test]
fn v1_profile_files_load_with_migration() {
    let dir = std::env::temp_dir().join("bitnet_tuning_test_v1");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v1.json");
    std::fs::write(
        &path,
        r#"{"version": 1, "threads": 1, "default": "I2_S",
            "entries": [{"m": 256, "k": 256, "n": 1, "best": "TL2_1", "measurements": []}]}"#,
    )
    .unwrap();
    let p = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(p.select(256, 256, 1), QuantType::Tl21);
    assert!(p.overrides.is_empty() && p.e2e.is_empty(), "v1 migrates to empty v2 sections");

    // Unknown versions fail with a clear error, not field-order luck.
    let path2 = dir.join("v99.json");
    std::fs::write(&path2, r#"{"version": 99, "threads": 1, "default": "I2_S", "entries": []}"#)
        .unwrap();
    let err = TuningProfile::load(&path2).unwrap_err();
    std::fs::remove_file(&path2).unwrap();
    assert!(format!("{err:#}").contains("supported"), "{err:#}");
}

#[test]
fn vector_winning_profile_degrades_under_forced_scalar() {
    // A profile tuned on an AVX2 host (best_simd = avx2 everywhere) is
    // force-loaded on a machine that can only run scalar: every
    // selection must degrade to the best *usable* measurement's kernel
    // — not silently serve the vector winner's kernel on the assumption
    // the vector path exists — and each degrade must be counted in the
    // dispatch-fallback accounting.
    use bitnet::kernels::simd;
    let cfg = ModelConfig::tiny();
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    for (m, k) in bitnet::kernels::tuner::shapes_for_model(&cfg) {
        profile.entries.push(TuningEntry {
            m,
            k,
            n: 1,
            weight: 1.0,
            best: QuantType::Tl21,
            best_simd: SimdLevel::Avx2,
            best_sparse: false,
            measurements: vec![
                Measurement {
                    qtype: QuantType::Tl21,
                    simd: SimdLevel::Avx2,
                    sparse: false,
                    us_per_matmul: 5.0,
                    gweights_per_s: (m * k) as f64 / 5.0e-6 / 1e9,
                },
                Measurement {
                    qtype: QuantType::I2S,
                    simd: SimdLevel::Scalar,
                    sparse: false,
                    us_per_matmul: 9.0,
                    gweights_per_s: (m * k) as f64 / 9.0e-6 / 1e9,
                },
            ],
        });
    }
    // The v3 per-level fields survive the disk round trip.
    let dir = std::env::temp_dir().join("bitnet_tuning_test_simd");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("vector_profile.json");
    profile.save(&path).unwrap();
    let loaded = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(loaded, profile, "best_simd / per-measurement simd must round-trip");

    simd::with_level(SimdLevel::Scalar, || {
        let ck = Checkpoint::synthetic(&cfg, 13);
        let model = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(loaded), 1);
        for (li, layer) in model.layers.iter().enumerate() {
            assert_eq!(
                layer.wq.qtype(),
                QuantType::I2S,
                "layer {li}: the scalar measurement's kernel must win under forced scalar"
            );
        }
        assert!(
            model.plan.fallbacks() > 0,
            "every degraded selection must surface in the fallback count"
        );
        let mut s = model.new_session(16);
        assert!(model.prefill(&mut s, &[1, 2, 3]).iter().all(|v| v.is_finite()));
    });
}

#[test]
fn v3_profile_files_load_with_dense_defaults() {
    // A verbatim v3 file (per-measurement simd levels, no sparse
    // fields): everything loads with the sparse dimension defaulting to
    // dense, and re-saving migrates to the current version.
    let dir = std::env::temp_dir().join("bitnet_tuning_test_v3");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v3.json");
    std::fs::write(
        &path,
        r#"{"version": 3, "threads": 1, "default": "I2_S",
            "entries": [{"m": 256, "k": 256, "n": 1, "best": "TL1_1", "best_simd": "avx2",
                "measurements": [{"kernel": "TL1_1", "simd": "avx2",
                                  "us_per_matmul": 7.0, "gweights_per_s": 9.4}]}]}"#,
    )
    .unwrap();
    let p = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(p.entries.len(), 1);
    assert!(!p.entries[0].best_sparse, "v3 winners migrate as dense");
    assert!(p.entries[0].measurements.iter().all(|m| !m.sparse));
    let resaved = p.to_json().to_string_pretty();
    assert!(resaved.contains("\"best_sparse\""), "re-save writes the v4 field");
}

#[test]
fn sparse_tuned_profile_degrades_when_sparse_packing_is_off() {
    // A profile whose winners were measured on the block-skip sparse
    // layout is served on a host with sparse packing disabled
    // (RUST_PALLAS_SPARSE=off / --sparse off): every tensor packs dense,
    // so selection must re-rank to the best dense measurement and count
    // the degrade — not silently serve the sparse-tuned winner.
    use bitnet::kernels::sparse::{self, SparseMode};
    let cfg = ModelConfig::tiny();
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    for (m, k) in bitnet::kernels::tuner::shapes_for_model(&cfg) {
        profile.entries.push(TuningEntry {
            m,
            k,
            n: 1,
            weight: 1.0,
            best: QuantType::Tl11,
            best_simd: SimdLevel::Scalar,
            best_sparse: true,
            measurements: vec![
                Measurement {
                    qtype: QuantType::Tl11,
                    simd: SimdLevel::Scalar,
                    sparse: true,
                    us_per_matmul: 4.0,
                    gweights_per_s: (m * k) as f64 / 4.0e-6 / 1e9,
                },
                Measurement {
                    qtype: QuantType::I2S,
                    simd: SimdLevel::Scalar,
                    sparse: false,
                    us_per_matmul: 9.0,
                    gweights_per_s: (m * k) as f64 / 9.0e-6 / 1e9,
                },
            ],
        });
    }
    // The v4 sparse fields survive the disk round trip.
    let dir = std::env::temp_dir().join("bitnet_tuning_test_sparse");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sparse_profile.json");
    profile.save(&path).unwrap();
    let loaded = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(loaded, profile, "best_sparse / per-measurement sparse must round-trip");

    let (m0, k0) = bitnet::kernels::tuner::shapes_for_model(&cfg)[0];
    sparse::with_mode(SparseMode::On, || {
        assert_eq!(loaded.select(m0, k0, 1), QuantType::Tl11, "sparse winner serves when permitted");
    });
    sparse::with_mode(SparseMode::Off, || {
        assert_eq!(loaded.select(m0, k0, 1), QuantType::I2S, "re-rank to the dense measurement");
        let ck = Checkpoint::synthetic(&cfg, 13);
        let model = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(loaded), 1);
        for (li, layer) in model.layers.iter().enumerate() {
            assert_eq!(layer.wq.qtype(), QuantType::I2S, "layer {li} degraded to dense winner");
            assert!(!layer.wq.sparse_layout(), "layer {li}: no tensor packs sparse under off");
        }
        assert!(model.plan.fallbacks() > 0, "degrades must surface in the fallback count");
        let mut s = model.new_session(16);
        assert!(model.prefill(&mut s, &[1, 2, 3]).iter().all(|v| v.is_finite()));
    });
}

#[test]
fn measure_e2e_reports_both_candidates_and_refuses_huge_presets() {
    let profile = tiny_profile(QuantType::Tl21);
    let cfg = ModelConfig::tiny();
    let entries = measure_e2e(&profile, &cfg, 1, 8, 4, 1).unwrap();
    assert_eq!(entries.len(), 2);
    assert_eq!(entries[0].label, "auto");
    assert!(entries[1].label.contains("I2_S"), "{}", entries[1].label);
    assert!(entries.iter().all(|e| e.prefill_tok_s > 0.0 && e.decode_tok_s > 0.0));
    // Oversized presets refuse rather than synthesize billions of params.
    assert!(measure_e2e(&profile, &ModelConfig::b7(), 1, 4, 2, 1).is_err());
}

#[test]
fn trace_round_trip_drives_tuned_shapes() {
    // The tentpole acceptance path: record a serving trace, persist it,
    // and tune from it — the profile's tuned (m, k, n) set must be
    // exactly the model's projection shapes × the trace's observed
    // batch widths, no fixed --batches fallback, with each entry
    // carrying its width's observed traffic fraction.
    let mut trace = ServingTrace::new();
    for _ in 0..2 {
        trace.record_prefill(6);
    }
    trace.record_prefill(3);
    for _ in 0..10 {
        trace.record_decode(1);
    }
    for _ in 0..5 {
        trace.record_decode(2);
    }
    trace.steps = 18;

    let dir = std::env::temp_dir().join("bitnet_trace_roundtrip_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    trace.save(&path).unwrap();
    let loaded = ServingTrace::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(loaded, trace, "trace must round-trip losslessly");

    let cfg = ModelConfig::tiny();
    let mut tcfg = TuneConfig {
        shapes: bitnet::kernels::tuner::shapes_for_model(&cfg),
        candidates: vec![QuantType::I2S],
        min_iters: 1,
        min_seconds: 0.0,
        ..TuneConfig::default()
    };
    tcfg.set_weighted_batches(&loaded.weighted_batches());
    assert_eq!(tcfg.batches, vec![1, 2, 3, 6], "observed widths, ascending");
    let profile = tune(&tcfg, None);

    let tuned: std::collections::BTreeSet<(usize, usize, usize)> =
        profile.entries.iter().map(|e| (e.m, e.k, e.n)).collect();
    let expected: std::collections::BTreeSet<(usize, usize, usize)> = tcfg
        .shapes
        .iter()
        .flat_map(|&(m, k)| [1usize, 2, 3, 6].into_iter().map(move |n| (m, k, n)))
        .collect();
    assert_eq!(tuned, expected, "tuned shapes must equal trace widths × model shapes");
    for e in &profile.entries {
        let want = match e.n {
            1 => 10.0 / 18.0,
            2 => 5.0 / 18.0,
            3 => 1.0 / 18.0,
            6 => 2.0 / 18.0,
            other => panic!("unexpected tuned width {other}"),
        };
        assert!((e.weight - want).abs() < 1e-12, "n={}: weight {} want {want}", e.n, e.weight);
    }
    // The weighted entries survive the disk round trip.
    let path2 = dir.join("profile.json");
    profile.save(&path2).unwrap();
    let back = TuningProfile::load(&path2).unwrap();
    std::fs::remove_file(&path2).unwrap();
    assert_eq!(back, profile);
}

#[test]
fn override_search_skips_compositions_identical_to_uniform() {
    // A homogeneous profile (one kernel wins everywhere, also the
    // default) leaves the search nothing real to try: every composition
    // pins exactly what uniform already selects, so nothing beyond the
    // baseline may be measured — timing noise must never install no-op
    // override rows.
    let cfg = ModelConfig::tiny();
    let profile = tiny_profile(QuantType::I2S); // default is I2_S too
    let search = OverrideSearchConfig {
        prefill_tokens: 4,
        decode_tokens: 4,
        decode_width: 1,
        prefill_weight: 0.5,
        candidates: vec![QuantType::I2S],
        min_gain: 0.0,
    };
    let mut lines = Vec::new();
    let mut sink = |s: &str| lines.push(s.to_string());
    let outcome = search_overrides(&profile, &cfg, 1, &search, Some(&mut sink)).unwrap();
    assert!(outcome.overrides.is_empty(), "no-op compositions must not be emitted");
    assert_eq!(outcome.winner, "uniform");
    assert_eq!(outcome.measurements.len(), 1, "only the uniform baseline gets timed");
    assert!(
        lines.iter().any(|l| l.contains("matches the uniform assignment")),
        "skips must be visible: {lines:?}"
    );
}

#[test]
fn override_search_probes_widths_beyond_n1() {
    // An n=1 override row shadows dispatch at every width, so a
    // candidate that matches uniform at n=1 but differs at the measured
    // prefill width is a REAL composition — it must be timed, not
    // skipped as a no-op.
    let cfg = ModelConfig::tiny();
    let mut profile = tiny_profile(QuantType::I2S); // n=1 winners: I2_S
    for (m, k) in bitnet::kernels::tuner::shapes_for_model(&cfg) {
        profile.entries.push(entry(m, k, 8, QuantType::Tl21)); // n=8: TL2_1
    }
    let search = OverrideSearchConfig {
        prefill_tokens: 8,
        decode_tokens: 4,
        decode_width: 1,
        prefill_weight: 0.5,
        // I2_S matches uniform at n=1 everywhere but pins prefill (n=8)
        // away from TL2_1 — a genuinely different composition.
        candidates: vec![QuantType::I2S],
        min_gain: 0.0,
    };
    let mut lines = Vec::new();
    let mut sink = |s: &str| lines.push(s.to_string());
    let outcome = search_overrides(&profile, &cfg, 1, &search, Some(&mut sink)).unwrap();
    assert!(
        outcome.measurements.len() > 1,
        "edges=I2_S differs from uniform at the measured prefill width and must be timed: {lines:?}"
    );
}

#[test]
fn measure_dispatch_e2e_supports_batched_decode_width() {
    use bitnet::kernels::tuner::measure_dispatch_e2e;
    let cfg = ModelConfig::tiny();
    let e = measure_dispatch_e2e(
        "w2",
        Dispatch::Auto(tiny_profile(QuantType::I2S)),
        &cfg,
        1,
        4,
        4,
        2,
    )
    .unwrap();
    assert_eq!(e.label, "w2");
    assert!(e.prefill_tok_s > 0.0 && e.decode_tok_s > 0.0, "{e:?}");
}

#[test]
fn override_search_never_emits_a_losing_composition() {
    // Property over several profile variants: the search either emits
    // nothing (uniform won) or emits a composition that beat uniform in
    // its own measure_e2e run — and the emitted rows always load.
    let cfg = ModelConfig::tiny();
    for uniform_kernel in [QuantType::I2S, QuantType::Tl21] {
        let profile = tiny_profile(uniform_kernel);
        let search = OverrideSearchConfig {
            prefill_tokens: 8,
            decode_tokens: 8,
            decode_width: 1,
            prefill_weight: 0.5,
            candidates: vec![QuantType::I2S, QuantType::Tl21],
            // Zero margin: the property under test is the exact
            // never-lose contract, not the noise gate.
            min_gain: 0.0,
        };
        let mut lines = Vec::new();
        let mut sink = |s: &str| lines.push(s.to_string());
        let outcome = search_overrides(&profile, &cfg, 1, &search, Some(&mut sink)).unwrap();
        assert!(
            outcome.measurements.iter().any(|e| e.label == "uniform"),
            "uniform baseline must be measured"
        );
        assert!(outcome.best_score >= outcome.uniform_score);
        assert!(
            lines.iter().any(|l| l.contains("winner") || l.contains("uniform assignment wins")),
            "decision must be visible in progress output: {lines:?}"
        );
        if outcome.overrides.is_empty() {
            assert_eq!(outcome.winner, "uniform");
            assert_eq!(outcome.best_score, outcome.uniform_score);
        } else {
            assert!(
                outcome.best_score > outcome.uniform_score,
                "emitted overrides must have beaten uniform: {} vs {}",
                outcome.best_score,
                outcome.uniform_score
            );
            assert!(
                outcome.measurements.iter().any(|e| e.label == outcome.winner),
                "winner {} must be among the measurements",
                outcome.winner
            );
            for o in &outcome.overrides {
                assert!(o.layer < cfg.n_layers, "override row names a real layer");
                assert_eq!(o.n, 1, "search pins at n=1 (extends to all widths)");
            }
            // The winning composition actually packs and runs.
            let mut p2 = profile.clone();
            p2.overrides = outcome.overrides.clone();
            let ck = Checkpoint::synthetic(&cfg, 11);
            let model = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(p2), 1);
            let mut s = model.new_session(16);
            assert!(model.prefill(&mut s, &[1, 2, 3]).iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn real_tune_run_yields_usable_profile() {
    // End-to-end: micro-benchmark two kernels on the tiny shapes with a
    // minimal budget, save, load, and pack a model through the result.
    let cfg = ModelConfig::tiny();
    let tcfg = TuneConfig {
        shapes: bitnet::kernels::tuner::shapes_for_model(&cfg),
        batches: vec![1],
        candidates: vec![QuantType::I2S, QuantType::Tl21],
        min_iters: 1,
        min_seconds: 0.002,
        ..TuneConfig::default()
    };
    let profile = tune(&tcfg, None);
    assert_eq!(profile.entries.len(), tcfg.shapes.len());

    let dir = std::env::temp_dir().join("bitnet_tuning_test_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tuned.json");
    profile.save(&path).unwrap();
    let loaded = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    let ck = Checkpoint::synthetic(&cfg, 1);
    let model = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(loaded), 1);
    let mut s = model.new_session(16);
    let logits = model.prefill(&mut s, &[1, 2, 3]);
    assert!(logits.iter().all(|v| v.is_finite()));
    // Both candidates are lossless, so whatever won, logits must equal
    // the fixed I2_S reference.
    let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(QuantType::I2S), 1);
    let mut sf = fixed.new_session(16);
    assert_eq!(fixed.prefill(&mut sf, &[1, 2, 3]), logits);
}
