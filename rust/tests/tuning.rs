//! Auto-tuned dispatch integration tests: profile round-trip through disk
//! (save → load → identical dispatch decisions) and the correctness smoke
//! test that `Auto` dispatch is bit-identical to `Fixed` for the lossless
//! kernels (TL1_1, TL2_1, I2_S).

use bitnet::kernels::tuner::{tune, Measurement, TuneConfig, TuningEntry};
use bitnet::kernels::{Dispatch, QuantType, TuningProfile};
use bitnet::model::{ModelConfig, Transformer};
use bitnet::model::weights::Checkpoint;

fn entry(m: usize, k: usize, n: usize, best: QuantType) -> TuningEntry {
    TuningEntry {
        m,
        k,
        n,
        best,
        measurements: vec![Measurement {
            qtype: best,
            us_per_matmul: 10.0,
            gweights_per_s: (m * k) as f64 / 10.0e-6 / 1e9,
        }],
    }
}

/// A hand-built profile covering every projection shape of the tiny
/// preset, pinning each to a chosen lossless kernel.
fn tiny_profile(best_for_all: QuantType) -> TuningProfile {
    let cfg = ModelConfig::tiny();
    let mut p = TuningProfile::empty(QuantType::I2S, 1);
    for (m, k) in bitnet::kernels::tuner::shapes_for_model(&cfg) {
        p.entries.push(entry(m, k, 1, best_for_all));
    }
    p
}

#[test]
fn profile_round_trip_preserves_dispatch_decisions() {
    let cfg = ModelConfig::tiny();
    let shapes = bitnet::kernels::tuner::shapes_for_model(&cfg);
    let mut profile = TuningProfile::empty(QuantType::I2S, 2);
    // Mix of winners across shapes and batches.
    let kinds = [QuantType::Tl20, QuantType::Tl11, QuantType::Tq20, QuantType::I2S];
    for (i, &(m, k)) in shapes.iter().enumerate() {
        profile.entries.push(entry(m, k, 1, kinds[i % kinds.len()]));
        profile.entries.push(entry(m, k, 4, kinds[(i + 1) % kinds.len()]));
    }

    let dir = std::env::temp_dir().join("bitnet_tuning_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("profile.json");
    profile.save(&path).unwrap();
    let loaded = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    assert_eq!(loaded, profile, "profile must round-trip losslessly");
    // The contract that matters: identical selections for every shape at
    // every batch size, including fallback shapes missing from the profile.
    for &(m, k) in &shapes {
        for n in [1usize, 2, 4, 8, 64] {
            assert_eq!(loaded.select(m, k, n), profile.select(m, k, n), "{m}x{k} n={n}");
        }
    }
    assert_eq!(loaded.select(12345, 678, 1), profile.select(12345, 678, 1));
}

#[test]
fn auto_dispatch_is_bit_identical_to_fixed_for_lossless_kernels() {
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 99);
    let tokens = [3u32, 1, 4, 1, 5, 9, 2, 6];
    for qt in [QuantType::I2S, QuantType::Tl11, QuantType::Tl21] {
        let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(qt), 1);
        let auto =
            Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(tiny_profile(qt)), 1);
        assert_eq!(auto.qtype, qt, "representative kernel under auto");
        let mut s1 = fixed.new_session(32);
        let mut s2 = auto.new_session(32);
        let l1 = fixed.prefill(&mut s1, &tokens);
        let l2 = auto.prefill(&mut s2, &tokens);
        assert_eq!(l1, l2, "{qt:?}: auto vs fixed logits must be bit-identical");
    }
}

#[test]
fn auto_dispatch_mixing_lossless_kernels_matches_fixed_i2s() {
    // Different lossless kernels per shape still produce the exact I2_S
    // logits — the model-level Figure-2 property, now via dispatch.
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 7);
    let mut profile = TuningProfile::empty(QuantType::I2S, 1);
    let lossless = [QuantType::I2S, QuantType::Tl11, QuantType::Tl21];
    for (i, (m, k)) in bitnet::kernels::tuner::shapes_for_model(&cfg).into_iter().enumerate() {
        profile.entries.push(entry(m, k, 1, lossless[i % lossless.len()]));
    }
    let auto = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(profile), 1);
    // The mix really is a mix.
    let kernels: std::collections::HashSet<_> =
        auto.kernel_summary().into_iter().map(|(_, _, q)| q).collect();
    assert!(kernels.len() > 1, "expected heterogeneous dispatch, got {kernels:?}");

    let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(QuantType::I2S), 1);
    let tokens = [5u32, 10, 400, 3, 77];
    let mut s1 = fixed.new_session(32);
    let mut s2 = auto.new_session(32);
    assert_eq!(fixed.prefill(&mut s1, &tokens), auto.prefill(&mut s2, &tokens));
}

#[test]
fn real_tune_run_yields_usable_profile() {
    // End-to-end: micro-benchmark two kernels on the tiny shapes with a
    // minimal budget, save, load, and pack a model through the result.
    let cfg = ModelConfig::tiny();
    let tcfg = TuneConfig {
        shapes: bitnet::kernels::tuner::shapes_for_model(&cfg),
        batches: vec![1],
        threads: 1,
        candidates: vec![QuantType::I2S, QuantType::Tl21],
        default: QuantType::I2S,
        min_iters: 1,
        min_seconds: 0.002,
    };
    let profile = tune(&tcfg, None);
    assert_eq!(profile.entries.len(), tcfg.shapes.len());

    let dir = std::env::temp_dir().join("bitnet_tuning_test_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tuned.json");
    profile.save(&path).unwrap();
    let loaded = TuningProfile::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    let ck = Checkpoint::synthetic(&cfg, 1);
    let model = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Auto(loaded), 1);
    let mut s = model.new_session(16);
    let logits = model.prefill(&mut s, &[1, 2, 3]);
    assert!(logits.iter().all(|v| v.is_finite()));
    // Both candidates are lossless, so whatever won, logits must equal
    // the fixed I2_S reference.
    let fixed = Transformer::from_checkpoint_dispatch(&ck, Dispatch::Fixed(QuantType::I2S), 1);
    let mut sf = fixed.new_session(16);
    assert_eq!(fixed.prefill(&mut sf, &[1, 2, 3]), logits);
}
