//! Cross-module integration: BTNZ file → packed model → engine → eval,
//! exercising every kernel on the same checkpoint.

use bitnet::coordinator::{Engine, EngineConfig, Request};
use bitnet::eval::{cloze_agreement, synthetic_cloze_set};
use bitnet::kernels::QuantType;
use bitnet::model::weights::Checkpoint;
use bitnet::model::{ModelConfig, Transformer};

#[test]
fn btnz_to_engine_pipeline() {
    let cfg = ModelConfig::tiny();
    let ck = Checkpoint::synthetic(&cfg, 77);
    let dir = std::env::temp_dir().join("bitnet_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipeline.btnz");
    bitnet::modelio::save(&ck, &path).unwrap();
    let loaded = bitnet::modelio::load(&path).unwrap();
    let model = Transformer::from_checkpoint(&loaded, QuantType::Tl20, 2);
    let engine = Engine::start(model, EngineConfig::default());
    let (tokens, _, stats) = engine.submit(Request::greedy(vec![1, 2, 3, 4], 10)).wait();
    assert_eq!(tokens.len(), 10);
    assert_eq!(stats.prompt_tokens, 4);
    std::fs::remove_file(&path).unwrap();
}

/// Every kernel drives the same model to a coherent generation: greedy
/// outputs across kernels agree for most steps (quantization differences
/// may eventually diverge a sampled path, but early tokens should match).
#[test]
fn kernels_agree_on_early_greedy_tokens() {
    let cfg = ModelConfig::tiny();
    let gen = |qt: QuantType| {
        let model = Transformer::synthetic(&cfg, qt, 31);
        let mut s = model.new_session(32);
        let mut logits = model.prefill(&mut s, &[5, 6, 7]);
        let mut out = Vec::new();
        for _ in 0..4 {
            let tok = bitnet::model::sampling::argmax(&logits);
            out.push(tok);
            logits = model.decode_step(&mut s, tok);
        }
        out
    };
    let reference = gen(QuantType::I2S);
    for qt in [QuantType::Tl11, QuantType::Tl21] {
        assert_eq!(gen(qt), reference, "{qt:?}");
    }
    // Fast kernels: at least the first greedy token matches.
    for qt in [QuantType::Tl10, QuantType::Tl20, QuantType::Tq20, QuantType::Tmac] {
        assert_eq!(gen(qt)[0], reference[0], "{qt:?} first token");
    }
}

#[test]
fn cloze_task_runs_across_kernels() {
    let cfg = ModelConfig::tiny();
    let items = synthetic_cloze_set(cfg.vocab_size, 6, 9);
    let reference = Transformer::synthetic(&cfg, QuantType::I2S, 55);
    for qt in [QuantType::Tl21, QuantType::Tl20, QuantType::Tq20] {
        let model = Transformer::synthetic(&cfg, qt, 55);
        let agreement = cloze_agreement(&model, &reference, &items);
        let min = if qt == QuantType::Tl21 { 1.0 } else { 0.5 };
        assert!(agreement >= min, "{qt:?} agreement {agreement}");
    }
}

/// The CLI surface works end to end (gen-model → run via library calls).
#[test]
fn config_driven_launch() {
    let text = r#"
[model]
preset = "tiny"
kernel = "TL2_1"
[engine]
threads = 2
max_batch = 4
"#;
    let cfg = bitnet::config::Config::parse(text).unwrap();
    let lc = bitnet::config::LaunchConfig::from_config(&cfg);
    assert_eq!(lc.kernel, "TL2_1");
    let qt = QuantType::parse(&lc.kernel).unwrap();
    let mcfg = ModelConfig::preset(&lc.model_preset).unwrap();
    let model = Transformer::synthetic(&mcfg, qt, 1);
    let mut s = model.new_session(8);
    let logits = model.prefill(&mut s, &[1]);
    assert_eq!(logits.len(), mcfg.vocab_size);
}
