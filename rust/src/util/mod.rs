//! Small shared substrates: IEEE-754 half-precision conversion, a seedable
//! PRNG (no external deps are available offline), and summary statistics.
//!
//! These exist because the offline crate set is limited to `xla`, `anyhow`
//! and `thiserror`; everything else in the stack is built from scratch.

pub mod f16;
pub mod rng;
pub mod stats;

pub use f16::{f16_to_f32, f32_to_f16};
pub use rng::Rng;
pub use stats::Summary;

/// Ceiling division for usize.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 32), 0);
        assert_eq!(round_up(1, 32), 32);
        assert_eq!(round_up(32, 32), 32);
        assert_eq!(round_up(33, 32), 64);
    }
}
