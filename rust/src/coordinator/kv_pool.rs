//! Paged KV-cache accounting (vLLM-style block allocator).
//!
//! The pool divides the engine's KV budget into fixed-size pages of
//! [`PAGE_TOKENS`] tokens and tracks which sequence holds which pages.
//! The scheduler admits a request only when its worst-case page demand
//! (prompt + max_new_tokens) fits — preventing mid-decode OOM-evictions.
//! Sessions grow page-by-page as they decode, so freed capacity from
//! finished sequences is immediately reusable (continuous batching).

use std::collections::HashMap;

/// Tokens per KV page.
pub const PAGE_TOKENS: usize = 16;

/// Page-granular KV budget manager.
pub struct KvPool {
    total_pages: usize,
    free_pages: Vec<u32>,
    /// seq id → held pages.
    held: HashMap<u64, Vec<u32>>,
    /// High-water mark for metrics.
    peak_used: usize,
}

impl KvPool {
    /// Pool sized for `max_tokens` total KV tokens across all sequences.
    /// The page count rounds *up*: flooring would silently discard up to
    /// `PAGE_TOKENS - 1` tokens of budget the caller paid for (e.g.
    /// `KvPool::new(100)` serving only 96), so the invariant is
    /// `total_pages * PAGE_TOKENS >= max_tokens`.
    pub fn new(max_tokens: usize) -> KvPool {
        let total_pages = Self::pages_for(max_tokens);
        KvPool {
            total_pages,
            free_pages: (0..total_pages as u32).rev().collect(),
            held: HashMap::new(),
            peak_used: 0,
        }
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn free_page_count(&self) -> usize {
        self.free_pages.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free_pages.len()
    }

    pub fn peak_used_pages(&self) -> usize {
        self.peak_used
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(tokens: usize) -> usize {
        crate::util::ceil_div(tokens, PAGE_TOKENS)
    }

    /// Can a sequence with this worst-case token demand be admitted now?
    pub fn can_admit(&self, worst_case_tokens: usize) -> bool {
        Self::pages_for(worst_case_tokens) <= self.free_pages.len()
    }

    /// Reserve pages for `seq` to cover `tokens` tokens total (idempotent
    /// growth: only the delta beyond current holdings is allocated).
    /// Returns false (no change) if the pool cannot satisfy the demand.
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> bool {
        let want = Self::pages_for(tokens);
        let have = self.held.get(&seq).map_or(0, |v| v.len());
        if want <= have {
            return true;
        }
        let need = want - have;
        if need > self.free_pages.len() {
            return false;
        }
        let entry = self.held.entry(seq).or_default();
        for _ in 0..need {
            entry.push(self.free_pages.pop().unwrap());
        }
        self.peak_used = self.peak_used.max(self.total_pages - self.free_pages.len());
        true
    }

    /// Release all pages held by `seq`.
    pub fn release(&mut self, seq: u64) {
        if let Some(pages) = self.held.remove(&seq) {
            self.free_pages.extend(pages);
        }
    }

    /// Pages held by `seq`.
    pub fn held_pages(&self, seq: u64) -> usize {
        self.held.get(&seq).map_or(0, |v| v.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        assert_eq!(KvPool::pages_for(0), 0);
        assert_eq!(KvPool::pages_for(1), 1);
        assert_eq!(KvPool::pages_for(16), 1);
        assert_eq!(KvPool::pages_for(17), 2);
    }

    #[test]
    fn budget_rounds_up_not_down() {
        // 100 tokens needs 7 pages (112 tokens); flooring to 6 would
        // strand 4 tokens of paid-for budget.
        let mut pool = KvPool::new(100);
        assert_eq!(pool.total_pages(), 7);
        assert!(
            pool.total_pages() * PAGE_TOKENS >= 100,
            "invariant: page capacity covers the requested budget"
        );
        assert!(pool.can_admit(100));
        assert!(pool.reserve(1, 100), "the full paid-for budget is reservable");
        // Exact multiples and zero stay exact.
        assert_eq!(KvPool::new(160).total_pages(), 10);
        assert_eq!(KvPool::new(0).total_pages(), 0);
    }

    #[test]
    fn reserve_and_release_cycle() {
        let mut pool = KvPool::new(160); // 10 pages
        assert!(pool.reserve(1, 50)); // 4 pages
        assert_eq!(pool.held_pages(1), 4);
        assert_eq!(pool.free_page_count(), 6);
        assert!(pool.reserve(2, 96)); // 6 pages
        assert_eq!(pool.free_page_count(), 0);
        assert!(!pool.can_admit(1));
        pool.release(1);
        assert_eq!(pool.free_page_count(), 4);
        assert!(pool.can_admit(64));
        assert!(!pool.can_admit(65));
    }

    #[test]
    fn growth_is_incremental() {
        let mut pool = KvPool::new(160);
        assert!(pool.reserve(7, 16)); // 1 page
        assert!(pool.reserve(7, 17)); // grow to 2
        assert_eq!(pool.held_pages(7), 2);
        assert!(pool.reserve(7, 10)); // shrink requests are no-ops
        assert_eq!(pool.held_pages(7), 2);
    }

    #[test]
    fn reserve_fails_atomically() {
        let mut pool = KvPool::new(32); // 2 pages
        assert!(pool.reserve(1, 16));
        assert!(!pool.reserve(2, 32), "2 pages not available");
        assert_eq!(pool.held_pages(2), 0, "failed reserve must not leak");
        assert_eq!(pool.free_page_count(), 1);
    }

    #[test]
    fn peak_tracking() {
        let mut pool = KvPool::new(160);
        pool.reserve(1, 80);
        pool.release(1);
        pool.reserve(2, 16);
        assert_eq!(pool.peak_used_pages(), 5);
    }

    #[test]
    fn release_unknown_seq_is_noop() {
        let mut pool = KvPool::new(64);
        pool.release(99);
        assert_eq!(pool.free_page_count(), 4);
    }
}
