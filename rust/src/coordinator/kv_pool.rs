//! Paged KV-cache arena (vLLM-style block allocator that **owns the
//! bytes**).
//!
//! The arena divides the engine's KV budget into fixed-size pages of
//! [`PAGE_TOKENS`] tokens and backs them with real storage: one K slab and
//! one V slab per transformer layer, page-granular, in
//! [`KvDtype::F32`] (bit-exact with the pre-paged contiguous layout) or
//! [`KvDtype::F16`] (half the resident bytes, `--kv-dtype f16`). A page id
//! addresses the same page-sized region in every layer's slabs, so a
//! sequence needs exactly one page table however deep the model is.
//!
//! Memory is **lazy**: slabs grow only when a page id is minted for the
//! first time, so resident bytes track the *peak pages actually used*,
//! not the worst-case budget. Freed pages are recycled before new ones
//! are minted (continuous batching keeps the footprint near the working
//! set).
//!
//! The arena is also the admission-control ledger the
//! [`super::scheduler::Scheduler`] consults: `reserve`/`release` move
//! pages between the free list and per-sequence page tables, and
//! preemptions (watermark admission ran out of room mid-decode) are
//! counted here for the engine metrics.

use crate::util::f16::f16_to_f32_fast;
use crate::util::{ceil_div, f32_to_f16};
use std::collections::HashMap;

/// Tokens per KV page.
pub const PAGE_TOKENS: usize = 16;

/// Element type a KV page stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvDtype {
    /// 4 bytes/element; bit-exact with the pre-paged contiguous cache.
    F32,
    /// 2 bytes/element; K/V rows round-trip through IEEE binary16 on
    /// append (half the resident bytes, small perplexity cost).
    F16,
}

impl KvDtype {
    /// Parse a CLI/config value (`f32` | `f16`, case-insensitive).
    pub fn parse(s: &str) -> Option<KvDtype> {
        if s.eq_ignore_ascii_case("f32") {
            Some(KvDtype::F32)
        } else if s.eq_ignore_ascii_case("f16") {
            Some(KvDtype::F16)
        } else {
            None
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
        }
    }

    pub fn elem_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
        }
    }
}

/// One layer's K (or V) storage: page-granular, grown lazily as pages are
/// minted.
enum Slab {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl Slab {
    fn new(dtype: KvDtype) -> Slab {
        match dtype {
            KvDtype::F32 => Slab::F32(Vec::new()),
            KvDtype::F16 => Slab::F16(Vec::new()),
        }
    }

    fn grow(&mut self, elems: usize) {
        match self {
            Slab::F32(v) => v.resize(v.len() + elems, 0.0),
            Slab::F16(v) => v.resize(v.len() + elems, 0),
        }
    }

    fn byte_len(&self) -> usize {
        match self {
            Slab::F32(v) => v.len() * 4,
            Slab::F16(v) => v.len() * 2,
        }
    }

    fn write_row(&mut self, off: usize, row: &[f32]) {
        match self {
            Slab::F32(v) => v[off..off + row.len()].copy_from_slice(row),
            Slab::F16(v) => {
                for (dst, &src) in v[off..off + row.len()].iter_mut().zip(row.iter()) {
                    *dst = f32_to_f16(src);
                }
            }
        }
    }

    /// The first `tn` rows of `page` as f32: borrowed straight from an
    /// F32 slab, or decoded into `scratch` for F16 (one decode per page
    /// per query row — the inner attention dot always runs over a
    /// contiguous f32 slice).
    fn page_rows<'a>(
        &'a self,
        page: u32,
        page_elems: usize,
        row_elems: usize,
        tn: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        let base = page as usize * page_elems;
        match self {
            Slab::F32(v) => &v[base..base + tn * row_elems],
            Slab::F16(v) => {
                scratch.clear();
                scratch.extend(v[base..base + tn * row_elems].iter().map(|&b| f16_to_f32_fast(b)));
                &scratch[..]
            }
        }
    }
}

/// Page-granular KV arena: budget ledger + page tables + backing slabs.
pub struct KvArena {
    n_layers: usize,
    kv_dim: usize,
    dtype: KvDtype,
    page_tokens: usize,
    total_pages: usize,
    /// Recycled page ids (released before `next_page` reached the cap).
    free_pages: Vec<u32>,
    /// Page ids minted so far == pages of slab storage actually resident.
    next_page: u32,
    /// seq id → page table (the indirection attention reads through).
    tables: HashMap<u64, Vec<u32>>,
    peak_used: usize,
    preemptions: u64,
    k_slabs: Vec<Slab>,
    v_slabs: Vec<Slab>,
}

impl KvArena {
    /// Arena sized for `max_tokens` total KV tokens across all sequences.
    /// The page count rounds *up*: flooring would silently discard up to
    /// `PAGE_TOKENS - 1` tokens of budget the caller paid for (e.g. a
    /// 100-token budget serving only 96), so the invariant is
    /// `total_pages * PAGE_TOKENS >= max_tokens`. No slab memory is
    /// allocated here — pages mint lazily on first reserve.
    pub fn new(n_layers: usize, kv_dim: usize, max_tokens: usize, dtype: KvDtype) -> KvArena {
        Self::with_page_tokens(n_layers, kv_dim, max_tokens, dtype, PAGE_TOKENS)
    }

    /// [`KvArena::new`] with an explicit page size (tests: `page_tokens`
    /// larger than every sequence degenerates to the contiguous layout,
    /// the bit-identity reference).
    pub fn with_page_tokens(
        n_layers: usize,
        kv_dim: usize,
        max_tokens: usize,
        dtype: KvDtype,
        page_tokens: usize,
    ) -> KvArena {
        assert!(page_tokens > 0, "page size must be positive");
        KvArena {
            n_layers,
            kv_dim,
            dtype,
            page_tokens,
            total_pages: ceil_div(max_tokens, page_tokens),
            free_pages: Vec::new(),
            next_page: 0,
            tables: HashMap::new(),
            peak_used: 0,
            preemptions: 0,
            k_slabs: (0..n_layers).map(|_| Slab::new(dtype)).collect(),
            v_slabs: (0..n_layers).map(|_| Slab::new(dtype)).collect(),
        }
    }

    /// A zero-layer arena: pure page accounting, no backing bytes
    /// (scheduler unit tests and page-math property tests).
    pub fn accounting(max_tokens: usize) -> KvArena {
        Self::new(0, 0, max_tokens, KvDtype::F32)
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Pages still allocatable (recycled free-list entries plus pages the
    /// budget allows but that were never minted).
    pub fn free_page_count(&self) -> usize {
        self.total_pages - self.used_pages()
    }

    /// Pages currently held by sequences.
    pub fn used_pages(&self) -> usize {
        self.next_page as usize - self.free_pages.len()
    }

    pub fn peak_used_pages(&self) -> usize {
        self.peak_used
    }

    /// Sequences preempted because a growth reservation found the arena
    /// exhausted (see [`super::scheduler::Scheduler::step`]).
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Count one preemption (called by the scheduler when it evicts).
    pub fn note_preemption(&mut self) {
        self.preemptions += 1;
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        ceil_div(tokens, self.page_tokens)
    }

    /// Can a sequence with this token demand be granted pages right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free_page_count()
    }

    /// Bytes of slab storage actually resident (minted pages only —
    /// grows to the peak working set, never to the unused budget).
    pub fn resident_bytes(&self) -> usize {
        self.k_slabs.iter().chain(self.v_slabs.iter()).map(Slab::byte_len).sum()
    }

    /// Bytes the full page budget would occupy if every page were minted.
    pub fn capacity_bytes(&self) -> usize {
        self.total_pages * self.page_bytes()
    }

    /// Bytes one page occupies across all layers (K and V).
    fn page_bytes(&self) -> usize {
        self.page_tokens * self.kv_dim * self.dtype.elem_bytes() * 2 * self.n_layers
    }

    /// Reserve pages for `seq` to cover `tokens` tokens total (idempotent
    /// growth: only the delta beyond current holdings is allocated).
    /// Returns false (no change) if the arena cannot satisfy the demand.
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> bool {
        let want = self.pages_for(tokens);
        let have = self.tables.get(&seq).map_or(0, |v| v.len());
        if want <= have {
            return true;
        }
        let need = want - have;
        if need > self.free_page_count() {
            return false;
        }
        let mut minted = Vec::with_capacity(need);
        for _ in 0..need {
            minted.push(self.alloc_page().expect("free_page_count checked above"));
        }
        self.tables.entry(seq).or_default().extend(minted);
        self.peak_used = self.peak_used.max(self.used_pages());
        true
    }

    fn alloc_page(&mut self) -> Option<u32> {
        if let Some(p) = self.free_pages.pop() {
            return Some(p);
        }
        if (self.next_page as usize) < self.total_pages {
            let p = self.next_page;
            self.next_page += 1;
            let elems = self.page_tokens * self.kv_dim;
            for slab in self.k_slabs.iter_mut().chain(self.v_slabs.iter_mut()) {
                slab.grow(elems);
            }
            Some(p)
        } else {
            None
        }
    }

    /// Release all pages held by `seq` (finish or preemption). The slab
    /// memory stays minted for reuse; only the ids return to the free
    /// list.
    pub fn release(&mut self, seq: u64) {
        if let Some(pages) = self.tables.remove(&seq) {
            self.free_pages.extend(pages);
        }
    }

    /// Pages held by `seq`.
    pub fn held_pages(&self, seq: u64) -> usize {
        self.tables.get(&seq).map_or(0, |v| v.len())
    }

    /// Bytes of KV storage backing `seq`'s held pages — what the
    /// sequence actually occupies, not its worst-case reservation.
    pub fn held_bytes(&self, seq: u64) -> usize {
        self.held_pages(seq) * self.page_bytes()
    }

    /// Write the K and V rows for token position `pos` of `seq` in
    /// `layer`. The covering page must already be reserved.
    pub fn append(&mut self, seq: u64, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.kv_dim);
        debug_assert_eq!(v.len(), self.kv_dim);
        let page = self.page_of(seq, pos);
        let off = (page as usize * self.page_tokens + pos % self.page_tokens) * self.kv_dim;
        self.k_slabs[layer].write_row(off, k);
        self.v_slabs[layer].write_row(off, v);
    }

    fn page_of(&self, seq: u64, pos: usize) -> u32 {
        let table = self.tables.get(&seq).expect("reserve pages before append/attend");
        *table.get(pos / self.page_tokens).unwrap_or_else(|| {
            panic!("KV arena: pos {pos} beyond {} reserved pages", table.len())
        })
    }

    /// K/V row for `pos` of `seq` in `layer`, decoded to f32 (debug/test
    /// accessor — the hot path reads whole pages via [`KvArena::attend`]).
    pub fn kv_row(&self, seq: u64, layer: usize, pos: usize) -> (Vec<f32>, Vec<f32>) {
        let page = self.page_of(seq, pos);
        let page_elems = self.page_tokens * self.kv_dim;
        let row = pos % self.page_tokens;
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let k = self.k_slabs[layer].page_rows(page, page_elems, self.kv_dim, row + 1, &mut ks);
        let k = k[row * self.kv_dim..(row + 1) * self.kv_dim].to_vec();
        let v = self.v_slabs[layer].page_rows(page, page_elems, self.kv_dim, row + 1, &mut vs);
        let v = v[row * self.kv_dim..(row + 1) * self.kv_dim].to_vec();
        (k, v)
    }

    /// Scaled-dot-product attention for one query row against `seq`'s
    /// cache in `layer`: context positions `0..ctx_len`, grouped-query
    /// heads, accumulated into `out` (assumed zeroed, `n_heads *
    /// head_dim`).
    ///
    /// The gather is tiled per page so the inner dot product always runs
    /// over a contiguous slice; per (head, position) arithmetic and
    /// accumulation order are identical to the pre-paged contiguous
    /// layout, so F32 results are bit-identical to it.
    #[allow(clippy::too_many_arguments)]
    pub fn attend(
        &self,
        seq: u64,
        layer: usize,
        q: &[f32],
        ctx_len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
        scale: f32,
        out: &mut [f32],
    ) {
        if ctx_len == 0 {
            return;
        }
        let kvd = self.kv_dim;
        let page_elems = self.page_tokens * kvd;
        let group = n_heads / n_kv_heads;
        let table = self.tables.get(&seq).expect("reserve pages before append/attend");
        let mut scores = vec![0f32; n_heads * ctx_len];
        let mut scratch: Vec<f32> = Vec::new();
        let mut t0 = 0usize;
        for &page in table.iter() {
            if t0 >= ctx_len {
                break;
            }
            let tn = self.page_tokens.min(ctx_len - t0);
            let kp = self.k_slabs[layer].page_rows(page, page_elems, kvd, tn, &mut scratch);
            for head in 0..n_heads {
                let kv_head = head / group;
                let qh = &q[head * head_dim..(head + 1) * head_dim];
                for t in 0..tn {
                    let kt = &kp[t * kvd + kv_head * head_dim..t * kvd + (kv_head + 1) * head_dim];
                    scores[head * ctx_len + t0 + t] =
                        qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
            }
            t0 += tn;
        }
        assert!(t0 >= ctx_len, "attend: page table covers {t0} of {ctx_len} context tokens");
        for head in 0..n_heads {
            crate::model::ops::softmax(&mut scores[head * ctx_len..(head + 1) * ctx_len]);
        }
        let mut t0 = 0usize;
        for &page in table.iter() {
            if t0 >= ctx_len {
                break;
            }
            let tn = self.page_tokens.min(ctx_len - t0);
            let vp = self.v_slabs[layer].page_rows(page, page_elems, kvd, tn, &mut scratch);
            for head in 0..n_heads {
                let kv_head = head / group;
                let oh = &mut out[head * head_dim..(head + 1) * head_dim];
                for t in 0..tn {
                    let w = scores[head * ctx_len + t0 + t];
                    let vt = &vp[t * kvd + kv_head * head_dim..t * kvd + (kv_head + 1) * head_dim];
                    for (o, &vv) in oh.iter_mut().zip(vt) {
                        *o += w * vv;
                    }
                }
            }
            t0 += tn;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        let arena = KvArena::accounting(0);
        assert_eq!(arena.pages_for(0), 0);
        assert_eq!(arena.pages_for(1), 1);
        assert_eq!(arena.pages_for(16), 1);
        assert_eq!(arena.pages_for(17), 2);
    }

    #[test]
    fn budget_rounds_up_not_down() {
        // 100 tokens needs 7 pages (112 tokens); flooring to 6 would
        // strand 4 tokens of paid-for budget.
        let mut arena = KvArena::accounting(100);
        assert_eq!(arena.total_pages(), 7);
        assert!(
            arena.total_pages() * PAGE_TOKENS >= 100,
            "invariant: page capacity covers the requested budget"
        );
        assert!(arena.can_admit(100));
        assert!(arena.reserve(1, 100), "the full paid-for budget is reservable");
        // Exact multiples and zero stay exact.
        assert_eq!(KvArena::accounting(160).total_pages(), 10);
        assert_eq!(KvArena::accounting(0).total_pages(), 0);
    }

    #[test]
    fn reserve_and_release_cycle() {
        let mut arena = KvArena::accounting(160); // 10 pages
        assert!(arena.reserve(1, 50)); // 4 pages
        assert_eq!(arena.held_pages(1), 4);
        assert_eq!(arena.free_page_count(), 6);
        assert!(arena.reserve(2, 96)); // 6 pages
        assert_eq!(arena.free_page_count(), 0);
        assert!(!arena.can_admit(1));
        arena.release(1);
        assert_eq!(arena.free_page_count(), 4);
        assert!(arena.can_admit(64));
        assert!(!arena.can_admit(65));
    }

    #[test]
    fn growth_is_incremental() {
        let mut arena = KvArena::accounting(160);
        assert!(arena.reserve(7, 16)); // 1 page
        assert!(arena.reserve(7, 17)); // grow to 2
        assert_eq!(arena.held_pages(7), 2);
        assert!(arena.reserve(7, 10)); // shrink requests are no-ops
        assert_eq!(arena.held_pages(7), 2);
    }

    #[test]
    fn reserve_fails_atomically() {
        let mut arena = KvArena::accounting(32); // 2 pages
        assert!(arena.reserve(1, 16));
        assert!(!arena.reserve(2, 32), "2 pages not available");
        assert_eq!(arena.held_pages(2), 0, "failed reserve must not leak");
        assert_eq!(arena.free_page_count(), 1);
    }

    #[test]
    fn peak_tracking() {
        let mut arena = KvArena::accounting(160);
        arena.reserve(1, 80);
        arena.release(1);
        arena.reserve(2, 16);
        assert_eq!(arena.peak_used_pages(), 5);
    }

    #[test]
    fn release_unknown_seq_is_noop() {
        let mut arena = KvArena::accounting(64);
        arena.release(99);
        assert_eq!(arena.free_page_count(), 4);
    }

    #[test]
    fn slabs_mint_lazily_and_recycle() {
        // 2 layers, kv_dim 4 → one page (16 tokens) costs
        // 16 tokens * 4 elems * 4 B * 2 (K+V) * 2 layers = 1024 B.
        let page_bytes = 16 * 4 * 4 * 2 * 2;
        let mut arena = KvArena::new(2, 4, 64, KvDtype::F32);
        assert_eq!(arena.total_pages(), 4);
        assert_eq!(arena.resident_bytes(), 0, "no pages minted up front");
        assert_eq!(arena.capacity_bytes(), 4 * page_bytes);
        assert!(arena.reserve(1, 10));
        assert_eq!(arena.resident_bytes(), page_bytes);
        assert_eq!(arena.held_bytes(1), page_bytes);
        assert!(arena.reserve(1, 30)); // second page
        assert_eq!(arena.resident_bytes(), 2 * page_bytes);
        arena.release(1);
        assert_eq!(arena.held_bytes(1), 0);
        // Recycled pages keep their storage: resident bytes don't move.
        assert!(arena.reserve(2, 32));
        assert_eq!(arena.resident_bytes(), 2 * page_bytes);
        assert!(arena.resident_bytes() <= arena.capacity_bytes());
    }

    #[test]
    fn f16_pages_halve_resident_bytes() {
        let mut a32 = KvArena::new(2, 4, 64, KvDtype::F32);
        let mut a16 = KvArena::new(2, 4, 64, KvDtype::F16);
        assert!(a32.reserve(1, 32));
        assert!(a16.reserve(1, 32));
        assert_eq!(a16.resident_bytes() * 2, a32.resident_bytes());
        assert_eq!(a16.capacity_bytes() * 2, a32.capacity_bytes());
    }

    #[test]
    fn append_read_round_trip_across_page_boundary() {
        let kvd = 4;
        let mut arena = KvArena::new(1, kvd, 64, KvDtype::F32);
        assert!(arena.reserve(9, 20)); // 2 pages: positions 0..=19
        for pos in [0usize, 15, 16, 19] {
            let k: Vec<f32> = (0..kvd).map(|i| (pos * 10 + i) as f32).collect();
            let v: Vec<f32> = (0..kvd).map(|i| -((pos * 10 + i) as f32)).collect();
            arena.append(9, 0, pos, &k, &v);
            let (rk, rv) = arena.kv_row(9, 0, pos);
            assert_eq!(rk, k, "K row at pos {pos}");
            assert_eq!(rv, v, "V row at pos {pos}");
        }
    }

    #[test]
    fn f16_rows_round_trip_within_half_precision() {
        let kvd = 8;
        let mut arena = KvArena::new(1, kvd, 32, KvDtype::F16);
        assert!(arena.reserve(1, 17));
        let k: Vec<f32> = (0..kvd).map(|i| 0.37 * (i as f32 + 1.0)).collect();
        let v: Vec<f32> = (0..kvd).map(|i| -1.625 * (i as f32 + 1.0)).collect();
        arena.append(1, 0, 16, &k, &v);
        let (rk, rv) = arena.kv_row(1, 0, 16);
        for (a, b) in rk.iter().zip(k.iter()).chain(rv.iter().zip(v.iter())) {
            let ulp = (b.abs() / 1024.0).max(6e-8);
            assert!((a - b).abs() <= ulp, "{a} vs {b}");
        }
    }

    #[test]
    fn preemption_counter() {
        let mut arena = KvArena::accounting(16);
        assert_eq!(arena.preemptions(), 0);
        arena.note_preemption();
        arena.note_preemption();
        assert_eq!(arena.preemptions(), 2);
    }
}
