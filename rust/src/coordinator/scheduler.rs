//! Continuous-batching scheduler: decides, each engine step, which waiting
//! requests to admit (prefill) and which running sequences decode — under
//! a max-batch-size cap and the [`KvPool`] page budget. Pure state
//! machine, no threads, so policies are unit-testable.
//!
//! Policy (vLLM-style FCFS):
//! * finished sequences release their pages immediately;
//! * waiting requests admit in arrival order while batch + KV allow;
//! * decode runs as one batch over everything in the running set.

use super::kv_pool::KvPool;
use std::collections::VecDeque;

/// Scheduler-side view of a sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub generated: usize,
    pub phase: Phase,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Waiting,
    /// Admitted; prompt not yet prefilled.
    Prefill,
    Decoding,
}

impl SeqState {
    /// Worst-case KV tokens this sequence can ever hold.
    pub fn worst_case_tokens(&self) -> usize {
        self.prompt_len + self.max_new_tokens
    }
    /// KV tokens committed so far (prompt once prefilled, plus sampled
    /// tokens — see [`Scheduler::kv_tokens_in_cache`]).
    pub fn current_tokens(&self) -> usize {
        match self.phase {
            Phase::Waiting => 0,
            Phase::Prefill => 0,
            Phase::Decoding => self.prompt_len + self.generated,
        }
    }
}

/// What the engine should do this step. Besides the request ids, the
/// plan carries the *shape* of the step — prefill chunk sizes and the
/// decode batch width — which is exactly what phase-aware kernel
/// dispatch keys on (a prefill chunk of 100 tokens and a decode batch
/// of 4 hit different tuned regimes; see `kernels::tuner::DispatchPlan`).
#[derive(Debug, Default, PartialEq, Eq)]
pub struct StepPlan {
    /// Newly admitted requests to prefill (in order).
    pub prefill: Vec<u64>,
    /// Prefill chunk size (prompt tokens) per admitted request, parallel
    /// to `prefill` — the GEMM batch width each prefill will run at.
    pub prefill_chunks: Vec<usize>,
    /// Running sequences to decode as one batch.
    pub decode: Vec<u64>,
}

impl StepPlan {
    /// The decode GEMM batch width of this step.
    pub fn decode_width(&self) -> usize {
        self.decode.len()
    }

    /// Total prompt tokens this step will prefill.
    pub fn prefill_tokens(&self) -> usize {
        self.prefill_chunks.iter().sum()
    }
}

/// The scheduler.
pub struct Scheduler {
    pub max_batch: usize,
    waiting: VecDeque<SeqState>,
    running: Vec<SeqState>,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Scheduler {
        Scheduler { max_batch: max_batch.max(1), waiting: VecDeque::new(), running: Vec::new() }
    }

    /// Enqueue a new request. Returns false if it can *never* be admitted
    /// (worst-case demand exceeds the whole pool).
    pub fn submit(&mut self, seq: SeqState, pool: &KvPool) -> bool {
        if KvPool::pages_for(seq.worst_case_tokens()) > pool.total_pages() {
            return false;
        }
        self.waiting.push_back(seq);
        true
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Mark a running sequence as having generated one more token.
    pub fn on_token(&mut self, id: u64) {
        if let Some(s) = self.running.iter_mut().find(|s| s.id == id) {
            s.generated += 1;
        }
    }

    /// Notification from the engine that `id`'s prompt is now in the KV
    /// cache. The `Prefill → Decoding` flip happens here — *after* the
    /// engine actually ran the prefill — not at planning time: flipping
    /// inside [`Scheduler::step`] made `current_tokens()` claim KV
    /// occupancy for prompts that were not yet prefilled, misreporting
    /// cache pressure for the duration of the step.
    pub fn on_prefilled(&mut self, id: u64) {
        if let Some(s) =
            self.running.iter_mut().find(|s| s.id == id && s.phase == Phase::Prefill)
        {
            s.phase = Phase::Decoding;
        }
    }

    /// KV tokens committed across every running sequence: resident
    /// prompt tokens plus every sampled token (the most recent of which
    /// is appended to the cache at the *next* decode step — committed
    /// occupancy, which is what capacity accounting needs, can lead
    /// physical residency by one token per decoding sequence).
    /// Admitted-but-unprefilled sequences contribute zero.
    pub fn kv_tokens_in_cache(&self) -> usize {
        self.running.iter().map(|s| s.current_tokens()).sum()
    }

    /// Remove a finished sequence and release its pages.
    pub fn finish(&mut self, id: u64, pool: &mut KvPool) {
        self.running.retain(|s| s.id != id);
        pool.release(id);
    }

    /// Plan one engine step: admit while room, then decode the batch.
    /// Admission reserves the *worst-case* page demand up front, so a
    /// sequence admitted here can always run to completion (no preemption
    /// needed — the paper's serving setting has no swapping tier).
    pub fn step(&mut self, pool: &mut KvPool) -> StepPlan {
        let mut plan = StepPlan::default();
        // Admit in FCFS order. Head-of-line blocking is intentional
        // (fairness): if the head doesn't fit, nothing behind it jumps.
        while self.running.len() < self.max_batch {
            let Some(head) = self.waiting.front() else { break };
            if !pool.reserve(head.id, head.worst_case_tokens()) {
                break;
            }
            let mut seq = self.waiting.pop_front().unwrap();
            seq.phase = Phase::Prefill;
            plan.prefill.push(seq.id);
            plan.prefill_chunks.push(seq.prompt_len);
            self.running.push(seq);
        }
        // Every running sequence decodes this step; newly admitted ones
        // stay in `Phase::Prefill` until the engine reports the prefill
        // actually happened (`on_prefilled`).
        for s in self.running.iter() {
            plan.decode.push(s.id);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(id: u64, prompt: usize, max_new: usize) -> SeqState {
        SeqState { id, prompt_len: prompt, max_new_tokens: max_new, generated: 0, phase: Phase::Waiting }
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut pool = KvPool::new(16 * 100);
        let mut sch = Scheduler::new(2);
        for i in 0..4 {
            assert!(sch.submit(seq(i, 8, 8), &pool));
        }
        let plan = sch.step(&mut pool);
        assert_eq!(plan.prefill, vec![0, 1]);
        assert_eq!(plan.decode, vec![0, 1]);
        assert_eq!(sch.waiting_len(), 2);
    }

    #[test]
    fn kv_budget_gates_admission() {
        let mut pool = KvPool::new(16 * 4); // 4 pages
        let mut sch = Scheduler::new(8);
        sch.submit(seq(1, 16, 16), &pool); // 2 pages
        sch.submit(seq(2, 16, 32), &pool); // 3 pages — won't fit after 1
        let plan = sch.step(&mut pool);
        assert_eq!(plan.prefill, vec![1]);
        assert_eq!(sch.waiting_len(), 1);
        // Finish 1 → 2 admits next step.
        sch.finish(1, &mut pool);
        let plan = sch.step(&mut pool);
        assert_eq!(plan.prefill, vec![2]);
    }

    #[test]
    fn oversized_request_rejected_at_submit() {
        let pool = KvPool::new(16 * 4);
        let mut sch = Scheduler::new(8);
        assert!(!sch.submit(seq(1, 100, 100), &pool));
        assert_eq!(sch.waiting_len(), 0);
    }

    #[test]
    fn fcfs_head_of_line() {
        let mut pool = KvPool::new(16 * 4);
        let mut sch = Scheduler::new(8);
        sch.submit(seq(1, 16, 48), &pool); // 4 pages
        sch.submit(seq(2, 8, 8), &pool); // 1 page — could fit, but behind 1
        let plan = sch.step(&mut pool);
        assert_eq!(plan.prefill, vec![1]);
        let plan = sch.step(&mut pool);
        assert!(plan.prefill.is_empty(), "2 must wait for 1's pages");
        assert_eq!(plan.decode, vec![1]);
    }

    #[test]
    fn continuous_batching_joins_mid_stream() {
        let mut pool = KvPool::new(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 4, 4), &pool);
        let p1 = sch.step(&mut pool);
        assert_eq!(p1.decode, vec![1]);
        sch.on_token(1);
        // New request joins while 1 is mid-decode.
        sch.submit(seq(2, 4, 4), &pool);
        let p2 = sch.step(&mut pool);
        assert_eq!(p2.prefill, vec![2]);
        assert_eq!(p2.decode, vec![1, 2]);
    }

    #[test]
    fn step_plan_reports_phase_shapes() {
        let mut pool = KvPool::new(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 5, 4), &pool);
        sch.submit(seq(2, 9, 4), &pool);
        let plan = sch.step(&mut pool);
        assert_eq!(plan.prefill_chunks, vec![5, 9]);
        assert_eq!(plan.prefill_tokens(), 14);
        assert_eq!(plan.decode_width(), 2);
        // Next step: no admissions, pure decode batch.
        let plan = sch.step(&mut pool);
        assert!(plan.prefill.is_empty() && plan.prefill_chunks.is_empty());
        assert_eq!(plan.prefill_tokens(), 0);
        assert_eq!(plan.decode_width(), 2);
    }

    #[test]
    fn phase_flips_on_engine_notification_not_at_planning() {
        let mut pool = KvPool::new(16 * 100);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 10, 4), &pool);
        let plan = sch.step(&mut pool);
        assert_eq!(plan.prefill, vec![1]);
        assert_eq!(plan.decode, vec![1], "admitted sequence still decodes this step");
        // Planning must NOT claim KV occupancy for a prompt the engine
        // has not prefilled yet.
        assert_eq!(sch.kv_tokens_in_cache(), 0, "prefill not yet executed");
        sch.on_prefilled(1);
        assert_eq!(sch.kv_tokens_in_cache(), 10, "prompt resident after prefill");
        sch.on_token(1);
        // Committed occupancy: the sampled token is counted now (it
        // enters the cache at the next decode step).
        assert_eq!(sch.kv_tokens_in_cache(), 11);
        // Later steps leave the phase alone.
        let plan = sch.step(&mut pool);
        assert!(plan.prefill.is_empty());
        assert_eq!(sch.kv_tokens_in_cache(), 11);
        // Unknown ids are a no-op.
        sch.on_prefilled(99);
    }

    #[test]
    fn finish_releases_pages() {
        let mut pool = KvPool::new(16 * 2);
        let mut sch = Scheduler::new(4);
        sch.submit(seq(1, 16, 16), &pool);
        sch.step(&mut pool);
        assert_eq!(pool.free_page_count(), 0);
        sch.finish(1, &mut pool);
        assert_eq!(pool.free_page_count(), 2);
        assert_eq!(sch.running_len(), 0);
    }
}
