//! Layer-3 coordinator: the serving system around the kernel library —
//! request router, the paged KV arena that owns the cache bytes
//! ([`kv_pool::KvArena`]), a continuous-batching scheduler with watermark
//! admission and LIFO preemption, and the engine event loop (the role
//! llama.cpp's `server` / vLLM's router play for the paper's system).
//!
//! Threading model: one engine thread owns the model and all sessions;
//! clients submit [`request::Request`]s over a channel and stream
//! [`request::Event`]s back. Python is never involved; the binary is
//! self-contained after `make artifacts`.

pub mod engine;
pub mod kv_pool;
pub mod request;
pub mod scheduler;
pub mod trace;

pub use engine::{Engine, EngineConfig};
pub use kv_pool::{KvArena, KvDtype, PAGE_TOKENS};
pub use request::{Event, FinishReason, Request, RequestHandle};
pub use trace::{ServingTrace, TraceRecorder};
