//! Persistent worker pool with a fork-join `parallel_for`, modeled on
//! ggml's compute threadpool: the same fixed set of threads executes every
//! mpGEMM row-range, so the thread-sweep experiments (paper Fig. 8 / Fig.
//! 10) measure kernel scaling rather than thread-spawn overhead.
//!
//! Design: N-1 parked workers plus the caller. A job is an `Arc<dyn Fn>`
//! over chunk indices plus an atomic chunk cursor (work stealing by atomic
//! fetch_add), so uneven rows still balance. The caller participates, then
//! waits on a completion latch.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Arc<dyn Fn(usize) + Send + Sync>;

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    work_done: Condvar,
}

struct State {
    job: Option<Job>,
    /// Total chunks in the current job.
    n_chunks: usize,
    /// Monotonic id so workers can tell jobs apart.
    epoch: u64,
    /// Chunks claimed so far (shared cursor).
    cursor: Arc<AtomicUsize>,
    /// Chunks finished so far.
    finished: usize,
    shutdown: bool,
}

/// A fixed-size pool. `size` counts the caller: `ThreadPool::new(1)` runs
/// everything inline with zero synchronization.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool that uses `size` threads in total (including the
    /// caller's thread). `size` is clamped to at least 1.
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                n_chunks: 0,
                epoch: 0,
                cursor: Arc::new(AtomicUsize::new(0)),
                finished: 0,
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
        });
        let workers = (1..size)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared))
            })
            .collect();
        ThreadPool { shared, workers, size }
    }

    /// Number of threads (including the caller).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(chunk)` for every `chunk in 0..n_chunks`, distributing chunks
    /// across all threads; returns when every chunk has completed.
    pub fn parallel_for<F>(&self, n_chunks: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n_chunks == 0 {
            return;
        }
        if self.size == 1 || n_chunks == 1 {
            for c in 0..n_chunks {
                f(c);
            }
            return;
        }
        // SAFETY of the transmute-free design: we wrap the borrowed closure
        // in an Arc with a 'static lifetime by boxing a shim that only lives
        // for the duration of this call; we block until all chunks complete
        // before returning, so the borrow cannot dangle.
        let f_ref: &(dyn Fn(usize) + Send + Sync) = &f;
        // SAFETY: the lifetime is erased only for the duration of this
        // call; the completion wait below blocks until every chunk has run,
        // so workers never touch the closure after `f` is dropped.
        let f_static: &'static (dyn Fn(usize) + Send + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let job: Job = Arc::new(move |c| f_static(c));

        let cursor = Arc::new(AtomicUsize::new(0));
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "parallel_for is not reentrant");
            st.job = Some(job);
            st.n_chunks = n_chunks;
            st.cursor = Arc::clone(&cursor);
            st.finished = 0;
            st.epoch += 1;
            self.shared.work_ready.notify_all();
        }

        // The caller participates in the same job.
        let mut mine = 0usize;
        loop {
            let c = cursor.fetch_add(1, Ordering::Relaxed);
            if c >= n_chunks {
                break;
            }
            f(c);
            mine += 1;
        }
        // Credit the caller's chunks and wait for the stragglers.
        let mut st = self.shared.state.lock().unwrap();
        st.finished += mine;
        while st.finished < st.n_chunks {
            st = self.shared.work_done.wait(st).unwrap();
        }
        st.job = None;
        st.n_chunks = 0;
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut last_epoch = 0u64;
    loop {
        // Wait for a new job (or shutdown).
        let (job, cursor, n_chunks) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job.clone() {
                    if st.epoch != last_epoch {
                        last_epoch = st.epoch;
                        break (job, Arc::clone(&st.cursor), st.n_chunks);
                    }
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        // Pull chunks until the cursor runs dry.
        let mut done = 0usize;
        loop {
            let c = cursor.fetch_add(1, Ordering::Relaxed);
            if c >= n_chunks {
                break;
            }
            job(c);
            done += 1;
        }
        let mut st = shared.state.lock().unwrap();
        st.finished += done;
        if st.finished >= st.n_chunks {
            shared.work_done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(hits.len(), |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = ThreadPool::new(1);
        let mut sum = 0u64;
        // Mutable capture works because size-1 pools run inline; use a cell
        // via atomics to keep the closure Fn.
        let total = AtomicU64::new(0);
        pool.parallel_for(10, |c| {
            total.fetch_add(c as u64, Ordering::SeqCst);
        });
        sum += total.load(Ordering::SeqCst);
        assert_eq!(sum, 45);
    }

    #[test]
    fn reusable_across_jobs() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let total = AtomicU64::new(0);
            pool.parallel_for(64, |c| {
                total.fetch_add((c + round) as u64, Ordering::SeqCst);
            });
            let expect: u64 = (0..64).map(|c| (c + round) as u64).sum();
            assert_eq!(total.load(Ordering::SeqCst), expect);
        }
    }

    #[test]
    fn zero_chunks_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn more_threads_than_chunks() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.parallel_for(3, |c| {
            total.fetch_add(c as u64 + 1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = ThreadPool::new(4);
        let data: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let chunks = 16;
        let partial: Vec<Mutex<f64>> = (0..chunks).map(|_| Mutex::new(0.0)).collect();
        let per = data.len() / chunks;
        pool.parallel_for(chunks, |c| {
            let s: f64 = data[c * per..(c + 1) * per].iter().sum();
            *partial[c].lock().unwrap() = s;
        });
        let total: f64 = partial.iter().map(|m| *m.lock().unwrap()).sum();
        assert_eq!(total, data.iter().sum::<f64>());
    }
}
