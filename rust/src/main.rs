//! `bitnet` binary: delegates to the serving layer's CLI entry point
//! (`pallas_serve::entry`). Kept in the facade crate so `cargo run` and
//! the binary name survive the workspace split unchanged.

fn main() {
    pallas_serve::entry::cli_main();
}
