//! Auto-tuned kernel dispatch (upstream bitnet.cpp's `kernel_tuning`
//! utility, reconstructed): micro-benchmark every applicable kernel for
//! the matmul shapes a model actually runs, persist the winners in a
//! [`TuningProfile`], and route every [`crate::model::BitLinear`] through
//! a [`Dispatch`] policy that either pins one kernel (`Fixed`) or selects
//! per shape from the profile (`Auto`).
//!
//! Why this exists: the paper's speedups (§4, Table 7) come from picking
//! the right mpGEMM kernel per machine *and* per matrix shape — TL2's
//! 1.67 bpw wins when decode is memory-bound, I2_S/TL1 win where the
//! LUT preprocessing dominates, and the crossover moves with m, k, batch
//! size and thread count. Upstream reports 20–30% extra throughput from
//! hardware-specific selection; this module makes that selection
//! measured rather than guessed.
//!
//! Flow:
//! 1. `bitnet tune --preset <p> --out profile.json` runs [`tune`] over the
//!    preset's projection shapes and writes the profile (JSON via
//!    [`crate::util::Json`]).
//! 2. `bitnet run --qtype auto --tune-profile profile.json` loads it into
//!    `Dispatch::Auto`, and each layer packs with the per-shape winner.
//!
//! Fallback semantics are documented on [`TuningProfile::select`] and in
//! `docs/tuning.md`.
#![deny(missing_docs)]

use super::{kernel_for, QuantType};
use crate::perf::calibrate::{calibrate_kernel_shape, KernelRate};
use crate::threadpool::ThreadPool;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Profile file format version (bump on breaking schema changes).
pub const PROFILE_VERSION: u64 = 1;

/// One timed kernel on one shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// The kernel measured.
    pub qtype: QuantType,
    /// Mean wall time of one matmul call, microseconds.
    pub us_per_matmul: f64,
    /// Weights streamed per second (`m·k / secs_per_call`), in units of
    /// 1e9 weights — the tuner's ranking metric (higher is better).
    pub gweights_per_s: f64,
}

/// Tuning result for one (m, k, batch) matmul shape.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningEntry {
    /// Output features (weight rows).
    pub m: usize,
    /// Input features (weight cols / reduction dim).
    pub k: usize,
    /// Activation batch rows the measurement used.
    pub n: usize,
    /// The fastest measured kernel for this shape.
    pub best: QuantType,
    /// All measurements, fastest first (kept for inspection/debugging).
    pub measurements: Vec<Measurement>,
}

/// A machine- and shape-specific kernel selection table, serializable to
/// a JSON profile file.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningProfile {
    /// Thread count the measurements were taken with (selection quality
    /// degrades if the serving thread count differs; the CLI warns).
    pub threads: usize,
    /// Fallback kernel for shapes absent from the profile.
    pub default: QuantType,
    /// Per-shape winners.
    pub entries: Vec<TuningEntry>,
}

impl TuningProfile {
    /// An empty profile that always falls back to `default`.
    pub fn empty(default: QuantType, threads: usize) -> TuningProfile {
        TuningProfile { threads, default, entries: Vec::new() }
    }

    /// Select the kernel for an `m`×`k` matmul at batch size `n`.
    ///
    /// Resolution order (documented contract, see docs/tuning.md):
    /// 1. the entry matching (m, k) with the **largest tuned batch ≤ n**
    ///    (decode at n=1 uses the n=1 entry; a batch of 6 uses the n=4
    ///    entry when 1 and 4 were tuned);
    /// 2. if every tuned batch for (m, k) exceeds `n`, the smallest one;
    /// 3. if (m, k) was never tuned at all, [`TuningProfile::default`].
    pub fn select(&self, m: usize, k: usize, n: usize) -> QuantType {
        let mut below: Option<&TuningEntry> = None;
        let mut above: Option<&TuningEntry> = None;
        for e in self.entries.iter().filter(|e| e.m == m && e.k == k) {
            if e.n <= n {
                if below.map_or(true, |b| e.n > b.n) {
                    below = Some(e);
                }
            } else if above.map_or(true, |a| e.n < a.n) {
                above = Some(e);
            }
        }
        below.or(above).map(|e| e.best).unwrap_or(self.default)
    }

    /// Serialize to the JSON profile schema.
    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|e| {
                let ms = e
                    .measurements
                    .iter()
                    .map(|m| {
                        Json::Obj(vec![
                            ("kernel".into(), Json::Str(m.qtype.name().into())),
                            ("us_per_matmul".into(), Json::Num(m.us_per_matmul)),
                            ("gweights_per_s".into(), Json::Num(m.gweights_per_s)),
                        ])
                    })
                    .collect();
                Json::Obj(vec![
                    ("m".into(), Json::Num(e.m as f64)),
                    ("k".into(), Json::Num(e.k as f64)),
                    ("n".into(), Json::Num(e.n as f64)),
                    ("best".into(), Json::Str(e.best.name().into())),
                    ("measurements".into(), Json::Arr(ms)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("version".into(), Json::Num(PROFILE_VERSION as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("default".into(), Json::Str(self.default.name().into())),
            ("entries".into(), Json::Arr(entries)),
        ])
    }

    /// Parse from the JSON profile schema.
    pub fn from_json(v: &Json) -> Result<TuningProfile> {
        let version = v.get("version").and_then(Json::as_usize).context("profile: version")?;
        if version as u64 != PROFILE_VERSION {
            bail!("unsupported profile version {version} (expected {PROFILE_VERSION})");
        }
        let threads = v.get("threads").and_then(Json::as_usize).context("profile: threads")?;
        let default = parse_qtype(v.get("default").and_then(Json::as_str).context("profile: default")?)?;
        let mut entries = Vec::new();
        for (i, e) in v
            .get("entries")
            .and_then(Json::as_array)
            .context("profile: entries")?
            .iter()
            .enumerate()
        {
            let field = |name: &str| {
                e.get(name).and_then(Json::as_usize).with_context(|| format!("entry {i}: {name}"))
            };
            let best = parse_qtype(
                e.get("best").and_then(Json::as_str).with_context(|| format!("entry {i}: best"))?,
            )?;
            let mut measurements = Vec::new();
            if let Some(ms) = e.get("measurements").and_then(Json::as_array) {
                for m in ms {
                    let (Some(kname), Some(us), Some(gw)) = (
                        m.get("kernel").and_then(Json::as_str),
                        m.get("us_per_matmul").and_then(Json::as_f64),
                        m.get("gweights_per_s").and_then(Json::as_f64),
                    ) else {
                        bail!("entry {i}: malformed measurement");
                    };
                    measurements.push(Measurement {
                        qtype: parse_qtype(kname)?,
                        us_per_matmul: us,
                        gweights_per_s: gw,
                    });
                }
            }
            entries.push(TuningEntry {
                m: field("m")?,
                k: field("k")?,
                n: field("n")?,
                best,
                measurements,
            });
        }
        Ok(TuningProfile { threads, default, entries })
    }

    /// Write the profile to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing profile {}", path.display()))
    }

    /// Load a profile from a JSON file.
    pub fn load(path: &Path) -> Result<TuningProfile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing profile {}", path.display()))?;
        Self::from_json(&v)
    }
}

fn parse_qtype(name: &str) -> Result<QuantType> {
    QuantType::parse(name).with_context(|| format!("unknown kernel {name:?} in profile"))
}

/// How a model picks the kernel for each of its ternary projections.
#[derive(Clone, Debug)]
pub enum Dispatch {
    /// Every projection uses this kernel (the pre-tuner behavior).
    Fixed(QuantType),
    /// Per-shape selection from a measured profile.
    Auto(TuningProfile),
}

impl Dispatch {
    /// The kernel for an `m`×`k` projection at decode batch `n`.
    pub fn select(&self, m: usize, k: usize, n: usize) -> QuantType {
        match self {
            Dispatch::Fixed(q) => *q,
            Dispatch::Auto(p) => p.select(m, k, n),
        }
    }

    /// A representative kernel (what `Transformer::qtype` reports): the
    /// fixed kernel, or the profile's selection for the given shape.
    pub fn representative(&self, m: usize, k: usize) -> QuantType {
        self.select(m, k, 1)
    }

    /// One-line human description for logs.
    pub fn describe(&self) -> String {
        match self {
            Dispatch::Fixed(q) => format!("fixed({})", q.name()),
            Dispatch::Auto(p) => format!(
                "auto({} tuned shapes, default {}, tuned @ {} threads)",
                p.entries.len(),
                p.default.name(),
                p.threads
            ),
        }
    }
}

/// What [`tune`] measures.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// (m, k) matmul shapes to tune (see [`shapes_for_model`]).
    pub shapes: Vec<(usize, usize)>,
    /// Activation batch sizes to tune each shape at.
    pub batches: Vec<usize>,
    /// Thread-pool size to measure with (match the serving `--threads`).
    pub threads: usize,
    /// Candidate kernels; non-applicable ones (k % k_multiple != 0) are
    /// skipped per shape.
    pub candidates: Vec<QuantType>,
    /// Fallback kernel recorded in the profile.
    pub default: QuantType,
    /// Minimum timed iterations per (kernel, shape).
    pub min_iters: usize,
    /// Minimum measurement wall time per (kernel, shape), seconds.
    pub min_seconds: f64,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            shapes: Vec::new(),
            batches: vec![1, 4],
            threads: 1,
            candidates: default_candidates(),
            default: QuantType::I2S,
            min_iters: 3,
            min_seconds: 0.06,
        }
    }
}

/// The default candidate set: compact ternary-native serving kernels
/// (storage ≤ 4 bpw). The dense baselines (F32/F16) and the general
/// llama.cpp formats (Q4_0/Q2_K) are excluded on purpose — a dense MAD
/// path can win a small cache-resident micro-benchmark, and silently
/// packing a "ternary" model at 16–32 bpw would defeat the 1-bit
/// serving premise. Measure them anyway with `--kernels`.
pub fn default_candidates() -> Vec<QuantType> {
    QuantType::ALL
        .iter()
        .copied()
        .filter(|&q| {
            let info = kernel_for(q).info();
            info.ternary_native && info.bpw <= 4.0
        })
        .collect()
}

/// The unique ternary-projection shapes of a model config, as (m, k) —
/// exactly the shapes [`crate::model::Transformer`] dispatches
/// ([`crate::model::ModelConfig::gemv_shapes`], deduplicated).
pub fn shapes_for_model(cfg: &crate::model::ModelConfig) -> Vec<(usize, usize)> {
    let mut shapes = cfg.gemv_shapes();
    shapes.sort_unstable();
    shapes.dedup();
    shapes
}

/// Micro-benchmark every applicable candidate on every (shape × batch)
/// and return the winners as a [`TuningProfile`]. `progress` (when given)
/// receives one line per measurement — the CLI wires it to stderr under
/// `--verbose`.
pub fn tune(cfg: &TuneConfig, mut progress: Option<&mut dyn FnMut(&str)>) -> TuningProfile {
    let pool = ThreadPool::new(cfg.threads.max(1));
    let mut entries = Vec::new();
    for &(m, k) in &cfg.shapes {
        for &n in &cfg.batches {
            if n == 0 {
                // A zero-row matmul measures nothing; an n=0 entry would
                // also shadow every real batch in `select` (e.n <= n).
                if let Some(p) = progress.as_mut() {
                    p(&format!("tune {m}x{k}: skipping batch 0 (no work to measure)"));
                }
                continue;
            }
            let mut measurements: Vec<Measurement> = Vec::new();
            for &qt in &cfg.candidates {
                if k % kernel_for(qt).info().k_multiple != 0 {
                    continue;
                }
                let rate: KernelRate =
                    calibrate_kernel_shape(qt, m, k, n, &pool, cfg.min_iters, cfg.min_seconds);
                let meas = Measurement {
                    qtype: qt,
                    us_per_matmul: rate.secs_per_matmul(m, k) * 1e6,
                    gweights_per_s: rate.weights_per_s / 1e9,
                };
                if let Some(p) = progress.as_mut() {
                    p(&format!(
                        "tune {m}x{k} n={n} {:<9} {:>10.1} µs/matmul ({:.2} Gw/s)",
                        qt.name(),
                        meas.us_per_matmul,
                        meas.gweights_per_s
                    ));
                }
                measurements.push(meas);
            }
            if measurements.is_empty() {
                continue;
            }
            measurements
                .sort_by(|a, b| a.us_per_matmul.partial_cmp(&b.us_per_matmul).expect("finite"));
            let best = measurements[0].qtype;
            if let Some(p) = progress.as_mut() {
                p(&format!("tune {m}x{k} n={n} -> best {}", best.name()));
            }
            entries.push(TuningEntry { m, k, n, best, measurements });
        }
    }
    TuningProfile { threads: cfg.threads.max(1), default: cfg.default, entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(m: usize, k: usize, n: usize, best: QuantType) -> TuningEntry {
        TuningEntry { m, k, n, best, measurements: Vec::new() }
    }

    #[test]
    fn select_prefers_largest_tuned_batch_not_above_n() {
        let p = TuningProfile {
            threads: 2,
            default: QuantType::I2S,
            entries: vec![
                entry(256, 256, 1, QuantType::Tl20),
                entry(256, 256, 4, QuantType::Tq20),
                entry(256, 256, 16, QuantType::F16),
            ],
        };
        assert_eq!(p.select(256, 256, 1), QuantType::Tl20);
        assert_eq!(p.select(256, 256, 3), QuantType::Tl20);
        assert_eq!(p.select(256, 256, 4), QuantType::Tq20);
        assert_eq!(p.select(256, 256, 9), QuantType::Tq20);
        assert_eq!(p.select(256, 256, 100), QuantType::F16);
    }

    #[test]
    fn select_falls_back_to_smallest_batch_then_default() {
        let p = TuningProfile {
            threads: 1,
            default: QuantType::I2S,
            entries: vec![entry(64, 512, 8, QuantType::Tl10)],
        };
        // Tuned batches all exceed n → smallest tuned batch.
        assert_eq!(p.select(64, 512, 1), QuantType::Tl10);
        // Unknown shape → default.
        assert_eq!(p.select(65, 512, 1), QuantType::I2S);
        assert_eq!(p.select(64, 513, 4), QuantType::I2S);
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let p = TuningProfile {
            threads: 4,
            default: QuantType::Tl20,
            entries: vec![TuningEntry {
                m: 768,
                k: 256,
                n: 1,
                best: QuantType::Tl21,
                measurements: vec![
                    Measurement {
                        qtype: QuantType::Tl21,
                        us_per_matmul: 12.5,
                        gweights_per_s: 15.7,
                    },
                    Measurement {
                        qtype: QuantType::I2S,
                        us_per_matmul: 14.0,
                        gweights_per_s: 14.0,
                    },
                ],
            }],
        };
        let back = TuningProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // And through the text form too.
        let text = p.to_json().to_string_pretty();
        let back2 = TuningProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back2, p);
    }

    #[test]
    fn from_json_rejects_bad_profiles() {
        assert!(TuningProfile::from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong_version =
            r#"{"version": 99, "threads": 1, "default": "I2_S", "entries": []}"#;
        assert!(TuningProfile::from_json(&Json::parse(wrong_version).unwrap()).is_err());
        let bad_kernel =
            r#"{"version": 1, "threads": 1, "default": "NOPE", "entries": []}"#;
        assert!(TuningProfile::from_json(&Json::parse(bad_kernel).unwrap()).is_err());
    }

    #[test]
    fn default_candidates_exclude_dense_and_general_formats() {
        let c = default_candidates();
        for q in [QuantType::I2S, QuantType::Tl20, QuantType::Tl11, QuantType::Tq10] {
            assert!(c.contains(&q), "{q:?} should be a default candidate");
        }
        for q in [QuantType::F32, QuantType::F16, QuantType::Q40, QuantType::Q2K] {
            assert!(!c.contains(&q), "{q:?} must not be packed by default auto-tuning");
        }
    }

    #[test]
    fn tune_skips_zero_batch() {
        let cfg = TuneConfig {
            shapes: vec![(16, 128)],
            batches: vec![0, 1],
            threads: 1,
            candidates: vec![QuantType::I2S],
            default: QuantType::I2S,
            min_iters: 1,
            min_seconds: 0.001,
        };
        let profile = tune(&cfg, None);
        assert_eq!(profile.entries.len(), 1);
        assert_eq!(profile.entries[0].n, 1);
    }

    #[test]
    fn shapes_for_model_covers_all_projections() {
        let cfg = crate::model::ModelConfig::tiny();
        let shapes = shapes_for_model(&cfg);
        assert!(shapes.contains(&(cfg.hidden, cfg.hidden)));
        assert!(shapes.contains(&(cfg.kv_dim(), cfg.hidden)));
        assert!(shapes.contains(&(cfg.ffn, cfg.hidden)));
        assert!(shapes.contains(&(cfg.hidden, cfg.ffn)));
        // Deduped and sorted.
        let mut sorted = shapes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(shapes, sorted);
    }

    #[test]
    fn tune_produces_entries_with_winners() {
        let cfg = TuneConfig {
            shapes: vec![(64, 256)],
            batches: vec![1],
            threads: 1,
            candidates: vec![QuantType::I2S, QuantType::Tl10],
            default: QuantType::I2S,
            min_iters: 2,
            min_seconds: 0.005,
        };
        let mut lines = Vec::new();
        let mut sink = |s: &str| lines.push(s.to_string());
        let profile = tune(&cfg, Some(&mut sink));
        assert_eq!(profile.entries.len(), 1);
        let e = &profile.entries[0];
        assert_eq!((e.m, e.k, e.n), (64, 256, 1));
        assert!(cfg.candidates.contains(&e.best));
        assert_eq!(e.measurements.len(), 2);
        assert!(e.measurements[0].us_per_matmul <= e.measurements[1].us_per_matmul);
        assert!(!lines.is_empty());
        // Selection from a freshly tuned profile resolves to the winner.
        assert_eq!(profile.select(64, 256, 1), e.best);
    }

    #[test]
    fn dispatch_policies_select_as_documented() {
        let fixed = Dispatch::Fixed(QuantType::Tl21);
        assert_eq!(fixed.select(10, 20, 1), QuantType::Tl21);
        assert!(fixed.describe().contains("TL2_1"));

        let mut p = TuningProfile::empty(QuantType::I2S, 1);
        p.entries.push(entry(256, 768, 1, QuantType::Tl11));
        let auto = Dispatch::Auto(p);
        assert_eq!(auto.select(256, 768, 1), QuantType::Tl11);
        assert_eq!(auto.select(512, 512, 1), QuantType::I2S, "missing shape → default");
        assert!(auto.describe().contains("auto"));
    }
}
