//! The ternary mpGEMM library — the paper's core contribution (§3, Table 1)
//! plus every baseline the evaluation compares against (§4, Table 7).
//!
//! | kernel | class | unit | bpw | lossless |
//! |--------|-------|------|-----|----------|
//! | `TL1_0`/`TL1_1` | LUT  | element-wise | 2.00 | ✗ / ✓ |
//! | `TL2_0`/`TL2_1` | LUT  | element-wise | 1.67 | ✗ / ✓ |
//! | `I2_S`          | MAD  | element-wise | 2.00 | ✓ |
//! | `TMAC` (stand-in)| LUT | bit-wise     | 2.00 | ✗ |
//! | `TQ1_0`         | MAD  | element-wise | 1.69 | ✗ |
//! | `TQ2_0`         | MAD  | element-wise | 2.06 | ✗ |
//! | `Q4_0`          | MAD  | bit-wise     | 4.50 | ✗ |
//! | `Q2_K`          | MAD  | bit-wise     | 2.63 | ✗ |
//! | `F16`           | MAD  | —            | 16.0 | — (full-precision baseline) |
//! | `ELUT4`/`ELUT5` | LUT  | element-wise | 2.00/2.50 | ✗ (appendix A extension) |
//!
//! All kernels consume the same [`quant::TernaryWeights`] (or raw f32 for
//! the general-purpose baselines) and produce f32 outputs, so they are
//! interchangeable inside the model and the quality/speed harnesses.

pub mod baselines;
pub mod counters;
pub mod elut;
pub mod i2s;
pub mod lut;
pub mod quant;
pub mod tl1;
pub mod tl2;
pub mod tuner;

pub use tuner::{Dispatch, DispatchPlan, Role, TuningProfile};

use crate::threadpool::ThreadPool;
use quant::{ActBlocked, ActInt8, TernaryWeights};

/// Every quantization type / kernel in the library (paper Table 1 +
/// baselines + appendix ELUT extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantType {
    /// f32 reference MAD path (stands in for llama.cpp Float32).
    F32,
    /// f16-stored weights, f32 MAD — the paper's "Float16" baseline.
    F16,
    /// llama.cpp Q4_0: 4-bit blocks of 32, general-purpose.
    Q40,
    /// llama.cpp Q2_K: 2-bit K-quants, multi-step dequant (§2.3).
    Q2K,
    /// llama.cpp TQ1_0: base-3 packed ternary, bpw 1.69, element-wise MAD.
    Tq10,
    /// llama.cpp TQ2_0: 2-bit ternary, bpw 2.06, element-wise MAD.
    Tq20,
    /// T-MAC style bit-wise LUT (2-bit, g=4, int8-requantized tables).
    Tmac,
    /// Paper TL1, int8-requantized LUT (fast, near-lossless).
    Tl10,
    /// Paper TL1, pack-and-unpack int16 LUT (lossless).
    Tl11,
    /// Paper TL2, mirror-consolidated g=3, int8 LUT (fast, bpw 1.67).
    Tl20,
    /// Paper TL2, int16 LUT (lossless, bpw 1.67).
    Tl21,
    /// Paper I2_S: element-wise MAD, per-tensor scales (lossless).
    I2S,
    /// Appendix ELUT with weight cardinality C=4 (alphabet ±1, ±3).
    Elut4,
    /// Appendix ELUT with weight cardinality C=5 (alphabet -2..2).
    Elut5,
}

/// Computational strategy (paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelClass {
    MadBased,
    LutBased,
}

/// Metadata describing a kernel (regenerates paper Table 1).
#[derive(Clone, Debug)]
pub struct KernelInfo {
    pub qtype: QuantType,
    /// Paper-facing name, e.g. "TL2_0".
    pub name: &'static str,
    pub class: KernelClass,
    /// Element-wise kernels exploit weight cardinality; bit-wise do not.
    pub element_wise: bool,
    /// Nominal bits per weight of the storage format.
    pub bpw: f64,
    /// Exactly reproduces the BitNet b1.58 training-scheme computation.
    pub lossless: bool,
    /// K must be a multiple of this for the kernel to apply.
    pub k_multiple: usize,
    /// Supports arbitrary ternary weights (false for general formats that
    /// merely *store* ternary models, e.g. Q4_0).
    pub ternary_native: bool,
}

impl QuantType {
    pub const ALL: [QuantType; 14] = [
        QuantType::F32,
        QuantType::F16,
        QuantType::Q40,
        QuantType::Q2K,
        QuantType::Tq10,
        QuantType::Tq20,
        QuantType::Tmac,
        QuantType::Tl10,
        QuantType::Tl11,
        QuantType::Tl20,
        QuantType::Tl21,
        QuantType::I2S,
        QuantType::Elut4,
        QuantType::Elut5,
    ];

    /// The set the paper's Table 7 sweeps (ternary-relevant kernels).
    pub const TABLE7: [QuantType; 8] = [
        QuantType::F16,
        QuantType::Q40,
        QuantType::Tmac,
        QuantType::Tq10,
        QuantType::Tq20,
        QuantType::Tl10,
        QuantType::Tl20,
        QuantType::I2S,
    ];

    pub fn name(&self) -> &'static str {
        kernel_for(*self).info().name
    }

    pub fn parse(s: &str) -> Option<QuantType> {
        QuantType::ALL
            .iter()
            .copied()
            .find(|q| q.name().eq_ignore_ascii_case(s))
    }
}

/// Prepared (quantized / tabulated) activations. Built once per activation
/// row, reused across all M weight rows — the "preprocessing stage" of
/// Algorithms 1 and 2.
pub enum Prepared {
    /// No quantization (F32/F16 baselines).
    Raw(Vec<f32>),
    /// Per-tensor int8 (BitNet training scheme).
    Int8(ActInt8),
    /// Per-block int8 (llama.cpp Q8_0 / Q8_K).
    Blocked(ActBlocked),
    /// Element-wise LUT, int16 entries (lossless TL path). `tables` holds
    /// `k/g` tables of 16 entries each; `scale` is the activation scale.
    LutI16 { tables: Vec<i16>, scale: f32 },
    /// Element-wise LUT requantized to int8 with one scale per k-block
    /// (fast TL path). `block_groups` = LUT groups per scale block.
    LutI8 { tables: Vec<i8>, block_scales: Vec<f32>, block_groups: usize, scale: f32 },
    /// Bit-wise LUT (T-MAC stand-in): int8 tables over 4-activation groups
    /// + per-block scales + activation sum for offset correction.
    BitLut { tables: Vec<i8>, block_scales: Vec<f32>, block_groups: usize, scale: f32, act_sum: i32 },
}

/// A packed weight tensor in some kernel's storage format.
pub struct QTensor {
    pub qtype: QuantType,
    pub m: usize,
    pub k: usize,
    /// Packed bytes, layout private to the kernel (row-major by weight row).
    pub data: Vec<u8>,
    /// Per-tensor weight scale (absmean `s`), where applicable.
    pub scale: f32,
}

impl QTensor {
    /// Achieved bits per weight of this packed tensor (regenerates the bpw
    /// column of Table 1 / Table 3 from real storage, not constants).
    pub fn bits_per_weight(&self) -> f64 {
        (self.data.len() as f64 * 8.0) / (self.m * self.k) as f64
    }

    /// Bytes that one GEMV must read from the weight side.
    pub fn weight_bytes(&self) -> usize {
        self.data.len()
    }
}

/// The kernel interface. One implementation per [`QuantType`].
pub trait Kernel: Send + Sync {
    fn info(&self) -> KernelInfo;

    /// Pack ternary weights into this kernel's storage format.
    fn quantize(&self, w: &TernaryWeights) -> QTensor;

    /// Reconstruct effective f32 weights (tests, quality eval).
    fn dequantize(&self, t: &QTensor) -> Vec<f32>;

    /// Quantize activations and (for LUT kernels) build lookup tables —
    /// Algorithm 1/2 "preprocessing" phase. `x.len() == k`.
    fn prepare(&self, x: &[f32], k: usize) -> Prepared;

    /// Compute `out[r] = Σ_k x[k] * W[r,k]` for `r` in `rows` —
    /// Algorithm 1/2 "accumulation" phase.
    fn gemv_rows(&self, t: &QTensor, p: &Prepared, out: &mut [f32], rows: std::ops::Range<usize>);

    /// Full single-row GEMV.
    fn gemv(&self, t: &QTensor, p: &Prepared, out: &mut [f32]) {
        assert_eq!(out.len(), t.m);
        self.gemv_rows(t, p, out, 0..t.m);
    }
}

/// Look up the kernel implementation for a quant type.
pub fn kernel_for(q: QuantType) -> &'static dyn Kernel {
    match q {
        QuantType::F32 => &baselines::f32_mad::F32Kernel,
        QuantType::F16 => &baselines::f16_mad::F16Kernel,
        QuantType::Q40 => &baselines::q4_0::Q40Kernel,
        QuantType::Q2K => &baselines::q2_k::Q2KKernel,
        QuantType::Tq10 => &baselines::tq1_0::Tq10Kernel,
        QuantType::Tq20 => &baselines::tq2_0::Tq20Kernel,
        QuantType::Tmac => &baselines::tmac::TmacKernel,
        QuantType::Tl10 => &tl1::TL1_0,
        QuantType::Tl11 => &tl1::TL1_1,
        QuantType::Tl20 => &tl2::TL2_0,
        QuantType::Tl21 => &tl2::TL2_1,
        QuantType::I2S => &i2s::I2SKernel,
        QuantType::Elut4 => &elut::ELUT4,
        QuantType::Elut5 => &elut::ELUT5,
    }
}

/// All kernel infos (regenerates paper Table 1).
pub fn library_table() -> Vec<KernelInfo> {
    QuantType::ALL.iter().map(|&q| kernel_for(q).info()).collect()
}

/// Multi-row, multi-threaded matmul: `out[(n, m)] = X[(n, k)] · Wᵀ`.
/// Preprocessing runs once per activation row; accumulation is chunked
/// over weight rows across the pool (llama.cpp parallelizes the same way).
pub fn matmul(
    kernel: &dyn Kernel,
    t: &QTensor,
    x: &[f32],
    n: usize,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    assert_eq!(x.len(), n * t.k);
    assert_eq!(out.len(), n * t.m);
    let m = t.m;
    // Row chunking: aim for ~4 chunks per thread for load balance.
    let chunks = (pool.size() * 4).min(m.max(1));
    let rows_per = crate::util::ceil_div(m, chunks);
    for i in 0..n {
        let p = kernel.prepare(&x[i * t.k..(i + 1) * t.k], t.k);
        let out_row = &mut out[i * m..(i + 1) * m];
        // SAFETY: chunks write disjoint ranges of out_row.
        let out_ptr = SendPtr(out_row.as_mut_ptr());
        pool.parallel_for(chunks, |c| {
            // Capture the whole wrapper (edition-2021 closures would
            // otherwise capture the raw-pointer field, which is !Sync).
            let out_ptr = &out_ptr;
            let lo = c * rows_per;
            if lo >= m {
                return;
            }
            let hi = ((c + 1) * rows_per).min(m);
            let slice = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo), hi - lo) };
            kernel.gemv_rows(t, &p, slice, lo..hi);
        });
    }
}

/// Pointer wrapper to move a raw pointer into the pool closure.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reference f64 GEMV over dequantized weights and raw activations.
    fn dense_ref(w: &[f32], m: usize, k: usize, x: &[f32]) -> Vec<f32> {
        (0..m)
            .map(|r| {
                w[r * k..(r + 1) * k]
                    .iter()
                    .zip(x.iter())
                    .map(|(&wv, &xv)| wv as f64 * xv as f64)
                    .sum::<f64>() as f32
            })
            .collect()
    }

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 0.0625)
    }

    /// Every kernel must approximate the dense reference within a
    /// quantization-error bound on random ternary weights.
    #[test]
    fn all_kernels_match_dense_reference() {
        let (m, k) = (64, 512);
        let t = random_ternary(m, k, 9);
        let wd = t.dequantize();
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let reference = dense_ref(&wd, m, k, &x);
        let ref_norm = reference.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();

        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let qt_tensor = kern.quantize(&t);
            let p = kern.prepare(&x, k);
            let mut out = vec![0f32; m];
            kern.gemv(&qt_tensor, &p, &mut out);
            let err = out
                .iter()
                .zip(reference.iter())
                .map(|(a, b)| ((*a - *b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let rel = err / ref_norm.max(1e-12);
            // Int8 activation quantization alone gives ~1e-3 relative error;
            // blocky baselines (Q2_K) are the loosest.
            let bound = match qt {
                QuantType::Q2K => 0.12,
                // Q4_0's asymmetric grid maps the −amax side to ±7/8 of
                // its value — up to ~12% error on exact-ternary data.
                QuantType::Q40 => 0.12,
                QuantType::Elut4 | QuantType::Elut5 => 0.08,
                // Bit-wise LUT requantizes subset-sum tables whose dynamic
                // range (up to 4·127) is wider than TL's pair/trio sums.
                QuantType::Tmac => 0.04,
                _ => 0.02,
            };
            assert!(rel < bound, "{}: rel err {rel:.5} >= {bound}", kern.info().name);
        }
    }

    /// Storage bpw must match the nominal Table-1 values.
    #[test]
    fn bpw_matches_table1() {
        let t = random_ternary(32, 3072, 11);
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if t.k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let got = packed.bits_per_weight();
            let want = kern.info().bpw;
            assert!(
                (got - want).abs() / want < 0.02,
                "{}: measured bpw {got:.3} vs nominal {want:.3}",
                kern.info().name
            );
        }
    }

    /// dequantize(quantize(w)) must preserve ternary values exactly for all
    /// ternary-native kernels.
    #[test]
    fn ternary_native_round_trip() {
        let t = random_ternary(16, 768, 12);
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            let info = kern.info();
            if !info.ternary_native || t.k % info.k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let back = kern.dequantize(&packed);
            let want = t.dequantize();
            for (i, (a, b)) in back.iter().zip(want.iter()).enumerate() {
                assert!((a - b).abs() < 1e-6, "{} idx {i}: {a} vs {b}", info.name);
            }
        }
    }

    /// matmul (threaded) must equal gemv row-by-row (serial).
    #[test]
    fn threaded_matmul_matches_serial() {
        let (m, k, n) = (48, 256, 3);
        let t = random_ternary(m, k, 13);
        let mut rng = Rng::new(14);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(4);
        for qt in [QuantType::I2S, QuantType::Tl20, QuantType::Tq20, QuantType::F16] {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let mut out_par = vec![0f32; n * m];
            matmul(kern, &packed, &x, n, &mut out_par, &pool);
            for i in 0..n {
                let p = kern.prepare(&x[i * k..(i + 1) * k], k);
                let mut out_ser = vec![0f32; m];
                kern.gemv(&packed, &p, &mut out_ser);
                assert_eq!(&out_par[i * m..(i + 1) * m], &out_ser[..], "{qt:?} row {i}");
            }
        }
    }

    #[test]
    fn quant_type_parse_round_trip() {
        for qt in QuantType::ALL {
            assert_eq!(QuantType::parse(qt.name()), Some(qt));
        }
        assert_eq!(QuantType::parse("tl2_0"), Some(QuantType::Tl20));
        assert_eq!(QuantType::parse("nope"), None);
    }

    #[test]
    fn library_table_has_expected_properties() {
        let table = library_table();
        assert_eq!(table.len(), QuantType::ALL.len());
        let tl2 = table.iter().find(|i| i.name == "TL2_0").unwrap();
        assert!(tl2.element_wise && tl2.class == KernelClass::LutBased && !tl2.lossless);
        let i2s = table.iter().find(|i| i.name == "I2_S").unwrap();
        assert!(i2s.lossless && i2s.class == KernelClass::MadBased);
        let tmac = table.iter().find(|i| i.name == "TMAC").unwrap();
        assert!(!tmac.element_wise);
    }
}
