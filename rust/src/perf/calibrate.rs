//! Kernel calibration: measured GEMV throughput on an out-of-cache
//! working set, used to compose the Table 7 / Figure 1 estimates for
//! model sizes that cannot be hosted dense (see DESIGN.md
//! §Substitutions — the paper's own N/A entries are the same phenomenon).

use crate::kernels::quant::TernaryWeights;
use crate::kernels::{kernel_for, matmul, QuantType};
use crate::threadpool::ThreadPool;
use crate::util::Rng;
use std::time::Instant;

/// Measured per-kernel GEMV throughput.
#[derive(Clone, Copy, Debug)]
pub struct KernelRate {
    pub qtype: QuantType,
    /// Packed weight bytes consumed per second of GEMV.
    pub weight_bytes_per_s: f64,
    /// Weights (elements) consumed per second.
    pub weights_per_s: f64,
    /// Achieved bits per weight of the packed tensor.
    pub bpw: f64,
}

/// Calibrate one kernel on an `m`×`k` GEMV with `pool` threads.
/// The working set should exceed LLC so rates are memory-realistic
/// (default shape 8192×8192 ≈ 17–134 MB depending on bpw).
pub fn calibrate_kernel(
    qtype: QuantType,
    m: usize,
    k: usize,
    pool: &ThreadPool,
    min_iters: usize,
) -> KernelRate {
    let kern = kernel_for(qtype);
    let mut rng = Rng::new(0xCA11);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let t = TernaryWeights::from_ternary(q, m, k, 0.05);
    let packed = kern.quantize(&t);
    let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
    let mut out = vec![0f32; m];
    // Warm.
    matmul(kern, &packed, &x, 1, &mut out, pool);
    // Measure at least `min_iters` and at least ~200ms.
    let t0 = Instant::now();
    let mut iters = 0usize;
    while iters < min_iters || t0.elapsed().as_secs_f64() < 0.2 {
        matmul(kern, &packed, &x, 1, &mut out, pool);
        iters += 1;
        if iters > 10_000 {
            break;
        }
    }
    let secs = t0.elapsed().as_secs_f64() / iters as f64;
    let bytes = packed.weight_bytes() as f64;
    KernelRate {
        qtype,
        weight_bytes_per_s: bytes / secs,
        weights_per_s: (m * k) as f64 / secs,
        bpw: packed.bits_per_weight(),
    }
}

/// Estimated decode tokens/s for a model config under a calibrated rate:
/// ternary projections at the measured kernel rate, LM head at the
/// measured F16 rate, plus a fixed per-token overhead for attention/norms.
pub fn tokens_per_second(
    cfg: &crate::model::ModelConfig,
    rate: &KernelRate,
    f16_rate: &KernelRate,
    overhead_s: f64,
) -> f64 {
    let ternary_bytes = cfg.ternary_param_count() as f64 * rate.bpw / 8.0;
    let head_bytes = (cfg.vocab_size * cfg.hidden) as f64 * 2.0;
    let t = ternary_bytes / rate.weight_bytes_per_s
        + head_bytes / f16_rate.weight_bytes_per_s
        + overhead_s;
    1.0 / t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_sane_rates() {
        let pool = ThreadPool::new(2);
        let r = calibrate_kernel(QuantType::I2S, 512, 1024, &pool, 3);
        assert!(r.weight_bytes_per_s > 1e6, "{:?}", r);
        assert!((r.bpw - 2.0).abs() < 0.01);
    }

    #[test]
    fn tokens_per_second_ordering() {
        let cfg = crate::model::ModelConfig::b3_8();
        let fast = KernelRate { qtype: QuantType::Tl20, weight_bytes_per_s: 1e10, weights_per_s: 5e10, bpw: 1.67 };
        let slow = KernelRate { qtype: QuantType::F16, weight_bytes_per_s: 1e10, weights_per_s: 5e9, bpw: 16.0 };
        let f16 = slow;
        let a = tokens_per_second(&cfg, &fast, &f16, 0.0);
        let b = tokens_per_second(&cfg, &slow, &f16, 0.0);
        assert!(a > b * 5.0, "{a} vs {b}");
    }
}
