//! **BitLinear**: the ternary linear layer of BitNet b1.58, dispatching
//! its mpGEMM through any kernel in the library. Holds the packed weight
//! tensor; activation quantization happens inside the kernel's `prepare`
//! so each kernel applies its own scheme (per-tensor for the lossless
//! kernels, per-block for the llama.cpp baselines — exactly the
//! distinction Figure 2 of the paper illustrates).

use crate::kernels::quant::TernaryWeights;
use crate::kernels::{kernel_for, matmul, Dispatch, Kernel, QTensor, QuantType};
use crate::threadpool::ThreadPool;

pub struct BitLinear {
    pub qtensor: QTensor,
    kernel: &'static dyn Kernel,
    /// Output features (rows).
    pub m: usize,
    /// Input features (cols).
    pub k: usize,
}

impl BitLinear {
    /// Pack ternary weights for the given kernel.
    pub fn new(w: &TernaryWeights, qtype: QuantType) -> BitLinear {
        let kernel = kernel_for(qtype);
        let info = kernel.info();
        assert_eq!(
            w.k % info.k_multiple,
            0,
            "{}: K={} not a multiple of {}",
            info.name,
            w.k,
            info.k_multiple
        );
        BitLinear { qtensor: kernel.quantize(w), kernel, m: w.m, k: w.k }
    }

    /// Pack ternary weights with the kernel a [`Dispatch`] policy selects
    /// for this layer's (m, k) shape — `Fixed` pins one kernel, `Auto`
    /// consults a measured [`crate::kernels::TuningProfile`] (decode-path
    /// batch of 1 is the selection key; see `docs/tuning.md`).
    pub fn from_dispatch(w: &TernaryWeights, dispatch: &Dispatch) -> BitLinear {
        Self::new(w, dispatch.select(w.m, w.k, 1))
    }

    pub fn qtype(&self) -> QuantType {
        self.kernel.info().qtype
    }

    /// Single-row forward: `out = W · x`.
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.k);
        debug_assert_eq!(out.len(), self.m);
        let p = self.kernel.prepare(x, self.k);
        self.kernel.gemv(&self.qtensor, &p, out);
    }

    /// Batched forward over `n` activation rows, parallelized on `pool`.
    pub fn forward_batch(&self, x: &[f32], n: usize, out: &mut [f32], pool: &ThreadPool) {
        matmul(self.kernel, &self.qtensor, x, n, out, pool);
    }

    /// Weight bytes this layer streams per token (memory-bound decode cost).
    pub fn weight_bytes(&self) -> usize {
        self.qtensor.weight_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_ternary(m: usize, k: usize, seed: u64) -> TernaryWeights {
        let mut rng = Rng::new(seed);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        TernaryWeights::from_ternary(q, m, k, 1.0 / (0.5 * k as f32).sqrt())
    }

    #[test]
    fn forward_matches_dense() {
        let (m, k) = (32, 256);
        let w = random_ternary(m, k, 1);
        let layer = BitLinear::new(&w, QuantType::I2S);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let mut out = vec![0f32; m];
        layer.forward(&x, &mut out);
        let wd = w.dequantize();
        for r in 0..m {
            let want: f32 = (0..k).map(|i| wd[r * k + i] * x[i]).sum();
            assert!((out[r] - want).abs() < 0.05 * want.abs().max(1.0), "row {r}");
        }
    }

    #[test]
    fn batch_forward_consistent_with_single() {
        let (m, k, n) = (16, 256, 4);
        let w = random_ternary(m, k, 3);
        let layer = BitLinear::new(&w, QuantType::Tl21);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(2);
        let mut out_b = vec![0f32; n * m];
        layer.forward_batch(&x, n, &mut out_b, &pool);
        for i in 0..n {
            let mut out_s = vec![0f32; m];
            layer.forward(&x[i * k..(i + 1) * k], &mut out_s);
            assert_eq!(&out_b[i * m..(i + 1) * m], &out_s[..], "row {i}");
        }
    }

    #[test]
    fn dispatch_packing_matches_fixed() {
        use crate::kernels::TuningProfile;
        let (m, k) = (16, 256);
        let w = random_ternary(m, k, 6);
        let mut profile = TuningProfile::empty(QuantType::I2S, 1);
        profile.entries.push(crate::kernels::tuner::TuningEntry {
            m,
            k,
            n: 1,
            best: QuantType::Tl21,
            measurements: Vec::new(),
        });
        let auto = BitLinear::from_dispatch(&w, &Dispatch::Auto(profile));
        assert_eq!(auto.qtype(), QuantType::Tl21);
        let fixed = BitLinear::from_dispatch(&w, &Dispatch::Fixed(QuantType::Tl21));
        assert_eq!(fixed.qtype(), QuantType::Tl21);
        assert_eq!(auto.qtensor.data, fixed.qtensor.data, "identical packing");
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn rejects_misaligned_k() {
        let w = random_ternary(4, 100, 5);
        BitLinear::new(&w, QuantType::I2S);
    }
}
