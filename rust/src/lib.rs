//! # bitnet-rs — Bitnet.cpp reproduction
//!
//! A from-scratch reproduction of *"Bitnet.cpp: Efficient Edge Inference for
//! Ternary LLMs"* (Wang et al., ACL 2025) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this crate)** — the inference engine: a ternary mpGEMM
//!   kernel library ([`kernels`]) with the paper's TL1/TL2/I2_S kernels and
//!   every baseline it compares against, a BitNet b1.58 transformer
//!   ([`model`]), a continuous-batching serving coordinator
//!   ([`coordinator`]), and the perf/eval harnesses that regenerate the
//!   paper's tables and figures ([`perf`], [`eval`]).
//! * **Layer 2** — `python/compile/model.py`: the same model in JAX,
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1** — `python/compile/kernels/ternary_matmul.py`: the
//!   element-wise LUT mpGEMM as a Pallas kernel, loaded and executed from
//!   Rust through [`runtime`] (PJRT, `xla` crate).
//!
//! Python never runs on the request path: artifacts are built once by
//! `make artifacts`; the serving binary is self-contained.
//!
//! ## Unsafe policy
//!
//! `unsafe` is confined to three audited sites: the explicit SIMD
//! implementations under `kernels/simd/` (intrinsics + documented
//! `# Safety` contracts), the bounds-free LUT reads in the scalar kernel
//! hot loops, and the disjoint-write pointer fan-out of the threaded
//! matmul. Every block carries a `// SAFETY:` comment; the
//! `undocumented_unsafe_blocks` clippy lint keeps it that way.
//!
//! ## Quick start
//!
//! ```no_run
//! use bitnet::kernels::{QuantType, kernel_for};
//! use bitnet::model::{ModelConfig, Transformer};
//!
//! // Build a tiny synthetic BitNet b1.58 model quantized with the lossless
//! // I2_S kernel and generate a few tokens.
//! let cfg = ModelConfig::tiny();
//! let model = Transformer::synthetic(&cfg, QuantType::I2S, 42);
//! let mut session = model.new_session(64);
//! let logits = model.prefill(&mut session, &[1, 2, 3]);
//! assert_eq!(logits.len(), cfg.vocab_size);
//! ```

#![warn(clippy::undocumented_unsafe_blocks)]

#[deny(unsafe_code)]
pub mod cli;
#[deny(unsafe_code)]
pub mod config;
#[deny(unsafe_code)]
pub mod coordinator;
#[deny(unsafe_code)]
pub mod eval;
pub mod kernels;
#[deny(unsafe_code)]
pub mod metrics;
pub mod model;
#[deny(unsafe_code)]
pub mod modelio;
#[deny(unsafe_code)]
pub mod perf;
#[deny(unsafe_code)]
pub mod runtime;
pub mod threadpool;
#[deny(unsafe_code)]
pub mod tokenizer;
pub mod util;

pub use kernels::{Dispatch, DispatchPlan, QuantType, Role, TuningProfile};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
