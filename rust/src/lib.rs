//! # bitnet-rs — Bitnet.cpp reproduction (facade)
//!
//! A from-scratch reproduction of *"Bitnet.cpp: Efficient Edge Inference for
//! Ternary LLMs"* (Wang et al., ACL 2025) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this workspace)** — the inference engine: a ternary mpGEMM
//!   kernel library ([`kernels`]) with the paper's TL1/TL2/I2_S kernels and
//!   every baseline it compares against, a BitNet b1.58 transformer
//!   ([`model`]), a continuous-batching serving coordinator
//!   ([`coordinator`]), and the perf/eval harnesses that regenerate the
//!   paper's tables and figures ([`perf`], [`eval`]).
//! * **Layer 2** — `python/compile/model.py`: the same model in JAX,
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1** — `python/compile/kernels/ternary_matmul.py`: the
//!   element-wise LUT mpGEMM as a Pallas kernel, loaded and executed from
//!   Rust through [`runtime`] (PJRT, `xla` crate).
//!
//! Python never runs on the request path: artifacts are built once by
//! `make artifacts`; the serving binary is self-contained.
//!
//! ## Workspace layout
//!
//! Since the crate split, this package (`rust_pallas`, lib name `bitnet`)
//! is a thin facade over four layered crates with an acyclic dependency
//! graph (see `docs/architecture.md`):
//!
//! * `pallas-core` — util, f16, json, rng, thread pool, NUMA topology,
//!   and the paged KV arena ([`bitnet::coordinator::kv_pool`] is a
//!   re-export of `pallas_core::arena`).
//! * `pallas-kernels` — `kernels/` (incl. sparse, tuner, counters, SIMD
//!   backends) and the `perf/` calibration harnesses.
//! * `pallas-model` — `model/`, `modelio`, `tokenizer`, `eval`, plus the
//!   model-building half of the tuner (`tuner_e2e`).
//! * `pallas-serve` — `coordinator/`, `metrics`, `runtime`, CLI + main.
//!
//! Every historical `bitnet::…` path keeps working through the
//! re-exports below; downstream code does not need to know which crate
//! an item landed in.
//!
//! ## Unsafe policy
//!
//! `unsafe` is confined to audited sites in `pallas-core` (thread-pool
//! lifetime erasure, NUMA thread pinning) and `pallas-kernels` /
//! `pallas-model` (SIMD intrinsics with documented `# Safety` contracts,
//! bounds-free LUT reads in the kernel hot loops, the disjoint-write
//! pointer fan-out of the threaded matmul). Every block carries a
//! `// SAFETY:` comment; the `undocumented_unsafe_blocks` clippy lint
//! keeps it that way.
//!
//! ## Quick start
//!
//! ```no_run
//! use bitnet::kernels::{QuantType, kernel_for};
//! use bitnet::model::{ModelConfig, Transformer};
//!
//! // Build a tiny synthetic BitNet b1.58 model quantized with the lossless
//! // I2_S kernel and generate a few tokens.
//! let cfg = ModelConfig::tiny();
//! let model = Transformer::synthetic(&cfg, QuantType::I2S, 42);
//! let mut session = model.new_session(64);
//! let logits = model.prefill(&mut session, &[1, 2, 3]);
//! assert_eq!(logits.len(), cfg.vocab_size);
//! ```

#![warn(clippy::undocumented_unsafe_blocks)]
#![deny(unsafe_code)]

pub use pallas_core::{simd, threadpool, topology, util};
pub use pallas_model::{eval, model, modelio, tokenizer};
pub use pallas_serve::{cli, config, coordinator, metrics, runtime};

/// The kernel library (`pallas_kernels::kernels`), with the tuner's
/// model-building e2e half (`pallas_model::tuner_e2e`) grafted back into
/// `kernels::tuner` so pre-split call sites compile unchanged.
pub mod kernels {
    pub use pallas_kernels::kernels::*;

    /// Auto-tuner: micro-benchmark sweep (`pallas-kernels`) plus the
    /// end-to-end measurement/override-search half that has to build
    /// whole models (`pallas_model::tuner_e2e`).
    pub mod tuner {
        pub use pallas_kernels::kernels::tuner::*;
        pub use pallas_model::tuner_e2e::{
            measure_dispatch_e2e, measure_e2e, search_overrides, shapes_for_model,
            OverrideSearchConfig, OverrideSearchOutcome,
        };
    }
}

/// Perf harnesses (`pallas_kernels::perf`), with the model-composed
/// throughput estimate re-exported back into `perf::calibrate`.
pub mod perf {
    pub use pallas_kernels::perf::*;

    /// Kernel calibration plus the model-level `tokens_per_second`
    /// estimate (which lives in `pallas-model` since the crate split —
    /// it needs `ModelConfig`).
    pub mod calibrate {
        pub use pallas_kernels::perf::calibrate::*;
        pub use pallas_model::tuner_e2e::tokens_per_second;
    }
}

pub use kernels::{Dispatch, DispatchPlan, QuantType, Role, TuningProfile};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
