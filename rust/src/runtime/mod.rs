//! PJRT runtime — loads the AOT artifacts produced by `python/compile/`
//! (Layer 1 Pallas kernel + Layer 2 JAX model lowered to HLO text) and
//! executes them on the `xla` crate's CPU PJRT client. This is the only
//! bridge between the Rust request path and the Python build path; Python
//! itself never runs at inference time.
//!
//! Interchange format is **HLO text** (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use crate::config::Config;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A loaded PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    pub fn describe(&self) -> String {
        format!("executable '{}'", self.name)
    }

    /// Execute with f32 inputs of the given shapes. The artifact is lowered
    /// with `return_tuple=True`, so the single output literal is a tuple;
    /// each element comes back as a flat f32 vector.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let n: usize = dims.iter().product();
                anyhow::ensure!(n == data.len(), "shape {:?} vs {} values", dims, data.len());
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = result.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}")))
            .collect()
    }

    /// Execute with deterministic pseudo-random inputs per the manifest
    /// entry (CLI smoke path).
    pub fn execute_random(&self, entry: &ManifestEntry) -> Result<Vec<Vec<f32>>> {
        let mut rng = crate::util::Rng::new(0xB17);
        let buffers: Vec<Vec<f32>> = entry
            .input_shapes
            .iter()
            .map(|dims| {
                let n: usize = dims.iter().product();
                (0..n).map(|_| rng.next_f32_signed()).collect()
            })
            .collect();
        let inputs: Vec<(&[f32], &[usize])> = buffers
            .iter()
            .zip(entry.input_shapes.iter())
            .map(|(b, d)| (b.as_slice(), d.as_slice()))
            .collect();
        self.execute_f32(&inputs)
    }
}

/// Input-shape metadata for one artifact, read from
/// `artifacts/manifest.toml` (written by `python/compile/aot.py`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
}

/// Parse a shape list like `"512;256x512"` → `[[512], [256, 512]]`.
pub fn parse_shapes(spec: &str) -> Result<Vec<Vec<usize>>> {
    spec.split(';')
        .filter(|s| !s.trim().is_empty())
        .map(|shape| {
            shape
                .trim()
                .split('x')
                .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad dim in {shape:?}")))
                .collect()
        })
        .collect()
}

/// Look up the manifest entry for an artifact path
/// (`<dir>/manifest.toml`, section named after the file stem).
pub fn manifest_for(artifact: &Path) -> Option<ManifestEntry> {
    let stem = artifact.file_stem()?.to_string_lossy().into_owned();
    // `foo.hlo.txt` → file_stem is `foo.hlo`; drop the inner extension too.
    let stem = stem.strip_suffix(".hlo").unwrap_or(&stem).to_string();
    let manifest_path: PathBuf = artifact.parent()?.join("manifest.toml");
    let cfg = Config::load(&manifest_path).ok()?;
    let spec = cfg.get(&format!("{stem}.inputs"))?.as_str()?.to_string();
    Some(ManifestEntry { name: stem, input_shapes: parse_shapes(&spec).ok()? })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_spec_parses() {
        assert_eq!(parse_shapes("512;256x512").unwrap(), vec![vec![512], vec![256, 512]]);
        assert_eq!(parse_shapes("4").unwrap(), vec![vec![4]]);
        assert!(parse_shapes("a").is_err());
    }

    // PJRT-backed tests live in rust/tests/runtime_pjrt.rs (they need the
    // artifacts built by `make artifacts`).
}
