//! Paper Table 2: end-to-end inference quality per kernel vs the
//! full-precision path — perplexity on a deterministic token stream and
//! accuracy on two synthetic cloze tasks (WinoGrande/HellaSwag stand-ins;
//! see DESIGN.md §Substitutions: the table's *claim* is equality to the
//! reference, which is corpus-independent).

use bitnet::eval::{cloze_choice, eval_token_stream, perplexity, synthetic_cloze_set};
use bitnet::kernels::sparse::{self, SparseMode};
use bitnet::kernels::QuantType;
use bitnet::model::{ModelConfig, Transformer};

fn main() {
    let cfg = ModelConfig::tiny();
    let tokens = eval_token_stream(cfg.vocab_size, 96, 1);
    let cloze_a = synthetic_cloze_set(cfg.vocab_size, 24, 2);
    let cloze_b = synthetic_cloze_set(cfg.vocab_size, 24, 3);

    // The full-precision reference path (paper's Float16 row): F32 MAD.
    let reference = Transformer::synthetic(&cfg, QuantType::F32, 7);
    let ref_ppl = perplexity(&reference, &tokens);
    let ref_a: Vec<usize> = cloze_a.iter().map(|it| cloze_choice(&reference, it)).collect();
    let ref_b: Vec<usize> = cloze_b.iter().map(|it| cloze_choice(&reference, it)).collect();

    println!("# Table 2 reproduction (synthetic corpus; agreement vs full-precision path)");
    println!(
        "{:<9} {:>11} {:>10} {:>10}  note",
        "Method", "Perplexity", "ClozeA %", "ClozeB %"
    );
    let kernels = [
        QuantType::F32,
        QuantType::Q40,
        QuantType::Tl10,
        QuantType::Tl20,
        QuantType::Tl11,
        QuantType::Tl21,
        QuantType::I2S,
    ];
    // Separately compute the integer reference once (I2_S) for the
    // losslessness note.
    let int_ref_ppl = perplexity(&Transformer::synthetic(&cfg, QuantType::I2S, 7), &tokens);
    for qt in kernels {
        let model = Transformer::synthetic(&cfg, qt, 7);
        let ppl = perplexity(&model, &tokens);
        let acc = |items: &[bitnet::eval::ClozeItem], refs: &[usize]| {
            let agree = items
                .iter()
                .zip(refs)
                .filter(|(it, &r)| cloze_choice(&model, it) == r)
                .count();
            100.0 * agree as f64 / items.len() as f64
        };
        let note = if ppl == int_ref_ppl && qt != QuantType::I2S {
            "lossless (== I2_S bitwise)"
        } else if qt == QuantType::I2S {
            "training-scheme reference"
        } else {
            ""
        };
        println!(
            "{:<9} {:>11.4} {:>10.1} {:>10.1}  {}",
            qt.name(),
            ppl,
            acc(&cloze_a, &ref_a),
            acc(&cloze_b, &ref_b),
            note
        );
    }
    // Sparse block-skip variants: forcing the layout on at pack time
    // must not move a single bit through the lossless kernels — the
    // elided blocks contribute exactly zero, so perplexity equals the
    // integer reference *exactly*, not approximately. A divergence here
    // is a kernel bug, so this lane asserts rather than annotates.
    println!("# sparse block-skip variants (packing forced on):");
    for qt in [QuantType::Tl11, QuantType::Tl21, QuantType::I2S] {
        let model = sparse::with_mode(SparseMode::On, || Transformer::synthetic(&cfg, qt, 7));
        let ppl = perplexity(&model, &tokens);
        assert_eq!(
            ppl,
            int_ref_ppl,
            "{}: the sparse layout must stay exactly lossless",
            qt.name()
        );
        let name = format!("{}+sp", qt.name());
        println!("{name:<9} {ppl:>11.4}  lossless (sparse == dense bitwise)");
    }
    println!("# Float16-path reference perplexity: {ref_ppl:.4}");
}
