//! Paper Table 7 + Figure 1: end-to-end decode tokens/s across model
//! sizes × kernels.
//!
//! Method (DESIGN.md E1): per-kernel GEMV rates are *measured* on an
//! out-of-LLC working set, then composed over each size's exact weight
//! byte counts (decode is memory-bound; the paper's own N/A entries show
//! even the authors could not host every size dense). Sizes that fit are
//! cross-checked end-to-end by examples/serve_e2e.rs.
//!
//! Env: BENCH_THREADS (default: all cores), BENCH_FAST=1 (smaller
//! calibration shape), BENCH_JSON=path (additionally write the rates and
//! per-size tokens/s as a JSON document — what CI uploads as the
//! `BENCH_e2e.json` perf-trajectory artifact).

use bitnet::coordinator::{Engine, EngineConfig, KvArena, KvDtype, Request, ServingTrace};
use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, matmul, matmul_prepared, PreparedActivations, QuantType};
use bitnet::model::weights::Checkpoint;
use bitnet::model::{ModelConfig, Transformer};
use bitnet::perf::calibrate::{calibrate_kernel, tokens_per_second, KernelRate};
use bitnet::threadpool::ThreadPool;
use bitnet::topology::{NumaMode, Topology};
use bitnet::util::{Json, Rng};
use bitnet::{Dispatch, DispatchPlan};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Run a short synthetic serving workload through the engine and return
/// the shape trace it recorded — the `tune --trace` input, reported here
/// so the perf trajectory shows which GEMM shapes serving actually ran
/// (and CI exercises the record path every build).
fn record_serving_trace(cfg: &ModelConfig, requests: usize) -> ServingTrace {
    let model = Transformer::synthetic(cfg, QuantType::I2S, 0xACE);
    let engine = Engine::start(
        model,
        EngineConfig { max_batch: 4, kv_budget_tokens: 4096, eos_token: 1, seed: 7, ..Default::default() },
    );
    let mut rng = Rng::new(0xACE);
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            let len = 2 + rng.next_below(10);
            let prompt: Vec<u32> =
                (0..len).map(|_| 3 + rng.next_below(cfg.vocab_size - 3) as u32).collect();
            engine.submit(Request::greedy(prompt, 2 + rng.next_below(8)))
        })
        .collect();
    for h in handles {
        let _ = h.wait();
    }
    engine.trace_snapshot()
}

/// KV-memory counters from a tight-budget serving workload under one KV
/// dtype: (resident bytes, budget bytes, peak pages, total pages,
/// preemptions). The budget is deliberately small so the run exercises
/// watermark admission and LIFO preemption; resident bytes show the lazy
/// arena's real footprint (f16 should be half of f32).
fn measure_kv_memory(
    cfg: &ModelConfig,
    dtype: KvDtype,
    requests: usize,
) -> (u64, u64, u64, u64, u64) {
    use std::sync::atomic::Ordering;
    let model = Transformer::synthetic(cfg, QuantType::I2S, 0xACE);
    let engine = Engine::start(
        model,
        EngineConfig {
            max_batch: 4,
            kv_budget_tokens: 128,
            eos_token: 1,
            seed: 7,
            kv_dtype: dtype,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(0xACE);
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            let len = 4 + rng.next_below(12);
            let prompt: Vec<u32> =
                (0..len).map(|_| 3 + rng.next_below(cfg.vocab_size - 3) as u32).collect();
            engine.submit(Request::greedy(prompt, 24))
        })
        .collect();
    for h in handles {
        let _ = h.wait();
    }
    let m = &engine.metrics;
    (
        m.kv_resident_bytes.load(Ordering::Relaxed),
        m.kv_capacity_bytes.load(Ordering::Relaxed),
        m.kv_pages_peak.load(Ordering::Relaxed),
        m.kv_pages_total.load(Ordering::Relaxed),
        m.kv_preemptions.load(Ordering::Relaxed),
    )
}

/// Prefix-cache counters from a shared-system-prompt workload under the
/// same tight KV budget, sharing off vs on: (prefill tokens computed,
/// prefix hit tokens, peak decode batch, COW splits). The seed request
/// runs alone so its prompt pages are indexed before the followers
/// submit; with sharing on, the followers map the system pages instead
/// of recomputing them — fewer prefill tokens and a wider co-run batch
/// out of the identical page budget.
fn measure_prefix_cache(
    cfg: &ModelConfig,
    prefix_cache: bool,
    followers: usize,
) -> (u64, u64, u64, u64) {
    use std::sync::atomic::Ordering;
    let model = Transformer::synthetic(cfg, QuantType::I2S, 0xACE);
    let engine = Engine::start(
        model,
        EngineConfig {
            max_batch: 4,
            kv_budget_tokens: 128,
            eos_token: 1,
            seed: 7,
            prefix_cache,
            ..Default::default()
        },
    );
    let system: Vec<u32> = (0u32..64).map(|i| 3 + (i * 7) % 500).collect();
    let mut seed_prompt = system.clone();
    seed_prompt.extend_from_slice(&[501, 502]);
    let _ = engine.submit(Request::greedy(seed_prompt, 6)).wait();
    let handles: Vec<_> = (0..followers as u32)
        .map(|i| {
            let mut p = system.clone();
            p.extend_from_slice(&[3 + i, 9 + i]);
            engine.submit(Request::greedy(p, 6))
        })
        .collect();
    for h in handles {
        let _ = h.wait();
    }
    let m = &engine.metrics;
    (
        m.prefill_tokens_computed.load(Ordering::Relaxed),
        m.prefix_hit_tokens.load(Ordering::Relaxed),
        m.peak_batch.load(Ordering::Relaxed),
        m.kv_cow_splits.load(Ordering::Relaxed),
    )
}

/// Measure real end-to-end prefill and decode throughput (tok/s) of a
/// synthetic model under one kernel — the phase split the prepare-once
/// pipeline targets (preprocessing reuse pays off mostly in prefill).
fn measure_model_e2e(
    qt: QuantType,
    cfg: &ModelConfig,
    threads: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
) -> (f64, f64) {
    let model = Transformer::from_checkpoint(&Checkpoint::synthetic(cfg, 0xE2E), qt, threads);
    let prompt: Vec<u32> = (0..prefill_tokens)
        .map(|i| (3 + i % cfg.vocab_size.saturating_sub(3).max(1)) as u32)
        .collect();
    let mut session = model.new_session(prefill_tokens + decode_tokens + 1);
    let t0 = Instant::now();
    let _ = model.prefill(&mut session, &prompt);
    let prefill_s = t0.elapsed().as_secs_f64().max(1e-9);
    let t1 = Instant::now();
    for _ in 0..decode_tokens {
        let _ = model.decode_step(&mut session, 3);
    }
    let decode_s = t1.elapsed().as_secs_f64().max(1e-9);
    (prefill_tokens as f64 / prefill_s, decode_tokens as f64 / decode_s)
}

/// Measure the prepare-reuse win directly: three projections consuming
/// one input, per-projection preparation (`matmul`) vs one shared
/// preparation (`PreparedActivations` + `matmul_prepared`). Returns
/// (legacy_us, shared_us) per matmul.
fn measure_prepare_reuse(
    qt: QuantType,
    m: usize,
    k: usize,
    n: usize,
    reps: usize,
    pool: &ThreadPool,
) -> (f64, f64) {
    let kern = kernel_for(qt);
    let mut rng = Rng::new(0xBEEF);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let t = TernaryWeights::from_ternary(q, m, k, 0.05);
    let packed = kern.quantize(&t);
    let x: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
    let mut out = vec![0f32; n * m];
    // Legacy pattern: every projection prepares for itself.
    matmul(kern, &packed, &x, n, &mut out, pool); // warm
    let t0 = Instant::now();
    for _ in 0..reps {
        for _ in 0..3 {
            matmul(kern, &packed, &x, n, &mut out, pool);
        }
    }
    let legacy = t0.elapsed().as_secs_f64() / (reps * 3) as f64;
    // Prepare-once pattern: qkv share one preparation.
    let mut acts = PreparedActivations::new();
    acts.begin_input();
    {
        let batch = acts.get_or_prepare(kern, &x, k, n, pool);
        matmul_prepared(kern, &packed, batch, &x, n, &mut out, pool); // warm
    }
    let t1 = Instant::now();
    for _ in 0..reps {
        acts.begin_input();
        for _ in 0..3 {
            let batch = acts.get_or_prepare(kern, &x, k, n, pool);
            matmul_prepared(kern, &packed, batch, &x, n, &mut out, pool);
        }
    }
    let shared = t1.elapsed().as_secs_f64() / (reps * 3) as f64;
    (legacy * 1e6, shared * 1e6)
}

/// Decode throughput of one model on one pool, with the KV arena's
/// page placement following the pool's topology — the measured half of
/// the NUMA section. Returns (decode tok/s, per-node resident KV bytes).
fn numa_run(
    cfg: &ModelConfig,
    pool: Arc<ThreadPool>,
    prefill_tokens: usize,
    decode_tokens: usize,
) -> (f64, Vec<usize>) {
    let plan = DispatchPlan::new(Dispatch::Fixed(QuantType::I2S));
    let model = Transformer::from_checkpoint_plan_pool(
        &Checkpoint::synthetic(cfg, 0xE2E),
        plan,
        Arc::clone(&pool),
    );
    let arena = Arc::new(Mutex::new({
        let mut a = KvArena::new(
            cfg.n_layers,
            cfg.kv_dim(),
            prefill_tokens + decode_tokens + 64,
            KvDtype::F32,
        );
        a.set_placement(pool);
        a
    }));
    let mut session = model.new_session_shared(&arena, 0, prefill_tokens + decode_tokens);
    let prompt: Vec<u32> = (0..prefill_tokens)
        .map(|i| (3 + i % cfg.vocab_size.saturating_sub(3).max(1)) as u32)
        .collect();
    let _ = model.prefill(&mut session, &prompt);
    let t0 = Instant::now();
    for _ in 0..decode_tokens {
        let _ = model.decode_step(&mut session, 3);
    }
    let tok_s = decode_tokens as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let kv = arena.lock().unwrap().resident_bytes_by_node().to_vec();
    (tok_s, kv)
}

fn main() {
    let threads: usize = std::env::var("BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    let fast = std::env::var("BENCH_FAST").is_ok();
    let (m, k) = if fast { (2048, 4096) } else { (8192, 8192) };
    let pool = ThreadPool::new(threads);
    println!("# Table 7 reproduction — calibration shape {m}x{k}, {threads} threads");

    let kernels = QuantType::TABLE7;
    let mut rates: Vec<KernelRate> = Vec::new();
    for qt in kernels {
        let r = calibrate_kernel(qt, m, k, &pool, 3);
        println!(
            "# calibrated {:<6} {:>7.2} GB/s weight stream, {:>7.2} Gweight/s (bpw {:.2})",
            qt.name(),
            r.weight_bytes_per_s / 1e9,
            r.weights_per_s / 1e9,
            r.bpw
        );
        rates.push(r);
    }
    let f16_rate = rates.iter().find(|r| r.qtype == QuantType::F16).copied().unwrap();

    // Per-token non-GEMM overhead: measured on the tiny model elsewhere;
    // attention/norm cost scales ~ with hidden·ctx — small next to the
    // weight stream at these sizes. Use 2% of the I2_S stream time.
    println!("\n{:<6} {}", "size", kernels.map(|q| format!("{:>9}", q.name())).join(" "));
    let mut rows = Vec::new();
    for cfg in ModelConfig::table7_sizes() {
        let mut row = format!("{:<6}", cfg.name);
        let mut vals = Vec::new();
        for r in &rates {
            // Paper marks Float16 N/A where the dense model exceeds RAM
            // (30B+ on the 64 GB testbed).
            let dense_gb = cfg.param_count() as f64 * r.bpw / 8.0 / 1e9;
            if dense_gb > 60.0 {
                row.push_str(&format!("{:>10}", "N/A"));
                vals.push(None);
                continue;
            }
            let overhead = cfg.ternary_param_count() as f64 * 0.25
                / rates.last().unwrap().weight_bytes_per_s
                * 0.02;
            let tps = tokens_per_second(&cfg, r, &f16_rate, overhead);
            row.push_str(&format!("{:>10.2}", tps));
            vals.push(Some(tps));
        }
        println!("{row}");
        rows.push((cfg, vals));
    }

    // Figure 1 headline ratios on the largest size each pair supports.
    let idx = |q: QuantType| kernels.iter().position(|&x| x == q).unwrap();
    let (cfg, vals) = rows.last().unwrap();
    println!("\n# Figure 1 ({} model):", cfg.name);
    let pairs = [
        ("I2_S / Float16 (largest co-hosted size)", QuantType::I2S, QuantType::F16),
        ("TL2_0 / TMAC", QuantType::Tl20, QuantType::Tmac),
        ("TL2_0 / TQ1_0", QuantType::Tl20, QuantType::Tq10),
        ("TL2_0 / Q4_0", QuantType::Tl20, QuantType::Q40),
    ];
    for (label, a, b) in pairs {
        // Find the largest size where both are available.
        let row = rows
            .iter()
            .rev()
            .find(|(_, v)| v[idx(a)].is_some() && v[idx(b)].is_some());
        if let Some((cfg, v)) = row {
            let ratio = v[idx(a)].unwrap() / v[idx(b)].unwrap();
            println!("#   {label}: {ratio:.2}x @ {}", cfg.name);
        }
    }
    let _ = vals;

    // Prepare-reuse microbenchmark: the shared-prepare pipeline vs
    // per-projection preparation on a prefill-shaped chunk. LUT kernels
    // (TL1/TL2) amortize their table build, so this is where the
    // prepare-once refactor's prefill win shows up.
    let (pm, pk, pn, reps) = if fast { (1024, 2048, 32, 3) } else { (4096, 4096, 64, 5) };
    println!("\n# Prepare reuse (3 projections/input, {pm}x{pk} n={pn}):");
    let reuse_kernels = [QuantType::Tl10, QuantType::Tl20, QuantType::Tl21, QuantType::I2S];
    let mut reuse_rows = Vec::new();
    for qt in reuse_kernels {
        let (legacy_us, shared_us) = measure_prepare_reuse(qt, pm, pk, pn, reps, &pool);
        let speedup = legacy_us / shared_us.max(1e-9);
        println!(
            "#   {:<6} per-call {legacy_us:>10.1} µs/matmul | shared {shared_us:>10.1} µs/matmul | {speedup:.2}x",
            qt.name()
        );
        reuse_rows.push((qt, legacy_us, shared_us, speedup));
    }

    // Measured end-to-end phase split (real transformer forward, not the
    // composed estimate above): prefill tok/s vs decode tok/s per kernel.
    let (e2e_cfg, e2e_prefill, e2e_decode) =
        if fast { (ModelConfig::tiny(), 64, 32) } else { (ModelConfig::m100(), 128, 64) };
    println!("\n# Measured e2e on preset {} ({threads} threads):", e2e_cfg.name);
    let e2e_kernels = [QuantType::I2S, QuantType::Tl10, QuantType::Tl20, QuantType::Tq20];
    let mut e2e_rows = Vec::new();
    for qt in e2e_kernels {
        let (prefill_tps, decode_tps) =
            measure_model_e2e(qt, &e2e_cfg, threads, e2e_prefill, e2e_decode);
        println!(
            "#   {:<6} prefill {prefill_tps:>8.1} tok/s | decode {decode_tps:>8.1} tok/s",
            qt.name()
        );
        e2e_rows.push((qt, prefill_tps, decode_tps));
    }

    // Serving-shape trace: run a short engine workload and report the
    // GEMM shape histogram it exhibits — the input `tune --trace` closes
    // the tuning loop with.
    let trace_requests = if fast { 8 } else { 16 };
    let trace = record_serving_trace(&ModelConfig::tiny(), trace_requests);
    println!("\n# Serving trace ({trace_requests} requests on tiny): {}", trace.summary());
    for (n, w) in trace.weighted_batches() {
        println!("#   batch width {n:>3}: {:>5.1}% of traffic", w * 100.0);
    }

    // KV arena memory under pressure: the same tight-budget workload in
    // f32 vs f16 pages — resident bytes (lazy minting), peak pages and
    // preemption counts the watermark scheduler incurred.
    let kv_requests = if fast { 6 } else { 12 };
    println!("\n# KV memory ({kv_requests} requests on tiny, 128-token budget):");
    let mut kv_rows = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::F16] {
        let (resident, budget, peak, total, preempt) =
            measure_kv_memory(&ModelConfig::tiny(), dtype, kv_requests);
        println!(
            "#   {:<4} resident {resident:>8} / {budget:>8} budget bytes | pages peak {peak}/{total} | {preempt} preemptions",
            dtype.name()
        );
        kv_rows.push((dtype, resident, budget, peak, total, preempt));
    }

    // Prefix sharing: the same tight page budget serving a 64-token
    // shared system prompt, cache off vs on. The win is twofold: the
    // shared prefix prefills once instead of per-request, and mapped
    // pages shrink each follower's footprint so more of them co-run.
    println!("\n# Prefix cache (64-token shared system prompt, 4 followers, 128-token budget):");
    let mut pc_rows = Vec::new();
    for on in [false, true] {
        let (computed, hit, peak, cow) = measure_prefix_cache(&ModelConfig::tiny(), on, 4);
        println!(
            "#   {:<3} prefill computed {computed:>5} tok | prefix hits {hit:>5} tok | peak batch {peak} | cow splits {cow}",
            if on { "on" } else { "off" }
        );
        pc_rows.push((on, computed, hit, peak, cow));
    }

    // NUMA placement: the same model and thread count on a single-node
    // pool vs split across nodes (per-node worker groups, localized
    // weights, placed GEMM routing, first-touched KV pages). Real
    // topology when the host has one; otherwise a mock split — placement
    // only, no pinning — so the partitioned code path is measured on any
    // CI box. Results are bit-identical either way; this section tracks
    // the throughput delta and the per-node counters.
    let host = Topology::detect(NumaMode::Auto);
    let numa_nodes = if host.n_nodes() > 1 { host.n_nodes() } else { 2 };
    let numa_topo = if host.n_nodes() > 1 { host } else { Topology::mock(numa_nodes) };
    let (numa_cfg, numa_prefill, numa_decode) =
        if fast { (ModelConfig::tiny(), 32, 24) } else { (ModelConfig::m100(), 64, 48) };
    let (numa_tok_s_1, _) =
        numa_run(&numa_cfg, Arc::new(ThreadPool::new(threads)), numa_prefill, numa_decode);
    let numa_pool = Arc::new(ThreadPool::with_topology(threads, numa_topo));
    let (numa_tok_s_n, numa_kv_bytes) =
        numa_run(&numa_cfg, Arc::clone(&numa_pool), numa_prefill, numa_decode);
    let numa_stats = numa_pool.numa_stats();
    println!(
        "\n# NUMA ({} nodes{}, {threads} threads, preset {}):",
        numa_stats.nodes,
        if numa_stats.mocked { " mocked" } else { "" },
        numa_cfg.name
    );
    println!(
        "#   decode {numa_tok_s_1:>8.1} tok/s @ 1 node | {numa_tok_s_n:>8.1} tok/s @ {} nodes",
        numa_stats.nodes
    );
    println!(
        "#   per-node chunks {} | per-node kv bytes {} | cross-node steals {}",
        numa_stats.chunks.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("/"),
        numa_kv_bytes.iter().map(|b| b.to_string()).collect::<Vec<_>>().join("/"),
        numa_stats.steals
    );

    // Machine-readable trajectory: one JSON document per run so CI can
    // archive the perf history (`BENCH_e2e.json` artifact).
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let rate_objs: Vec<Json> = rates
            .iter()
            .map(|r| {
                Json::Obj(vec![
                    ("kernel".into(), Json::Str(r.qtype.name().into())),
                    ("weight_gb_per_s".into(), Json::Num(r.weight_bytes_per_s / 1e9)),
                    ("gweights_per_s".into(), Json::Num(r.weights_per_s / 1e9)),
                    ("bpw".into(), Json::Num(r.bpw)),
                ])
            })
            .collect();
        let size_objs: Vec<Json> = rows
            .iter()
            .map(|(cfg, vals)| {
                let mut fields = vec![("size".to_string(), Json::Str(cfg.name.into()))];
                for (qt, v) in kernels.iter().zip(vals.iter()) {
                    let cell = match v {
                        Some(tps) => Json::Num(*tps),
                        None => Json::Null,
                    };
                    fields.push((qt.name().to_string(), cell));
                }
                Json::Obj(fields)
            })
            .collect();
        let reuse_objs: Vec<Json> = reuse_rows
            .iter()
            .map(|(qt, legacy_us, shared_us, speedup)| {
                Json::Obj(vec![
                    ("kernel".into(), Json::Str(qt.name().into())),
                    ("per_call_us_per_matmul".into(), Json::Num(*legacy_us)),
                    ("shared_us_per_matmul".into(), Json::Num(*shared_us)),
                    ("speedup".into(), Json::Num(*speedup)),
                ])
            })
            .collect();
        let e2e_objs: Vec<Json> = e2e_rows
            .iter()
            .map(|(qt, prefill_tps, decode_tps)| {
                Json::Obj(vec![
                    ("kernel".into(), Json::Str(qt.name().into())),
                    ("prefill_tok_s".into(), Json::Num(*prefill_tps)),
                    ("decode_tok_s".into(), Json::Num(*decode_tps)),
                ])
            })
            .collect();
        let kv_objs: Vec<Json> = kv_rows
            .iter()
            .map(|(dtype, resident, budget, peak, total, preempt)| {
                Json::Obj(vec![
                    ("dtype".into(), Json::Str(dtype.name().into())),
                    ("resident_bytes".into(), Json::Num(*resident as f64)),
                    ("budget_bytes".into(), Json::Num(*budget as f64)),
                    ("peak_pages".into(), Json::Num(*peak as f64)),
                    ("total_pages".into(), Json::Num(*total as f64)),
                    ("preemptions".into(), Json::Num(*preempt as f64)),
                ])
            })
            .collect();
        let pc_objs: Vec<Json> = pc_rows
            .iter()
            .map(|(on, computed, hit, peak, cow)| {
                Json::Obj(vec![
                    ("prefix_cache".into(), Json::Bool(*on)),
                    ("prefill_tokens_computed".into(), Json::Num(*computed as f64)),
                    ("prefix_hit_tokens".into(), Json::Num(*hit as f64)),
                    ("peak_batch".into(), Json::Num(*peak as f64)),
                    ("cow_splits".into(), Json::Num(*cow as f64)),
                ])
            })
            .collect();
        let doc = Json::Obj(vec![
            ("bench".into(), Json::Str("e2e_table7".into())),
            ("threads".into(), Json::Num(threads as f64)),
            ("fast".into(), Json::Bool(fast)),
            (
                "calibration_shape".into(),
                Json::Arr(vec![Json::Num(m as f64), Json::Num(k as f64)]),
            ),
            ("rates".into(), Json::Arr(rate_objs)),
            ("tokens_per_s".into(), Json::Arr(size_objs)),
            ("prepare_reuse".into(), Json::Arr(reuse_objs)),
            ("e2e_measured".into(), Json::Arr(e2e_objs)),
            ("serving_trace".into(), trace.to_json()),
            ("kv_memory".into(), Json::Arr(kv_objs)),
            ("prefix_cache".into(), Json::Arr(pc_objs)),
            (
                "numa".into(),
                Json::Obj(vec![
                    ("nodes".into(), Json::Num(numa_stats.nodes as f64)),
                    ("mocked".into(), Json::Bool(numa_stats.mocked)),
                    ("preset".into(), Json::Str(numa_cfg.name.into())),
                    ("decode_tok_s_1node".into(), Json::Num(numa_tok_s_1)),
                    ("decode_tok_s_nnodes".into(), Json::Num(numa_tok_s_n)),
                    (
                        "per_node_chunks".into(),
                        Json::Arr(numa_stats.chunks.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                    (
                        "per_node_kv_bytes".into(),
                        Json::Arr(numa_kv_bytes.iter().map(|&b| Json::Num(b as f64)).collect()),
                    ),
                    ("cross_node_steals".into(), Json::Num(numa_stats.steals as f64)),
                ]),
            ),
        ]);
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_JSON");
        println!("# wrote {path}");
    }
}
