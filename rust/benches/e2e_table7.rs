//! Paper Table 7 + Figure 1: end-to-end decode tokens/s across model
//! sizes × kernels.
//!
//! Method (DESIGN.md E1): per-kernel GEMV rates are *measured* on an
//! out-of-LLC working set, then composed over each size's exact weight
//! byte counts (decode is memory-bound; the paper's own N/A entries show
//! even the authors could not host every size dense). Sizes that fit are
//! cross-checked end-to-end by examples/serve_e2e.rs.
//!
//! Env: BENCH_THREADS (default: all cores), BENCH_FAST=1 (smaller
//! calibration shape), BENCH_JSON=path (additionally write the rates and
//! per-size tokens/s as a JSON document — what CI uploads as the
//! `BENCH_e2e.json` perf-trajectory artifact).

use bitnet::kernels::QuantType;
use bitnet::model::ModelConfig;
use bitnet::perf::calibrate::{calibrate_kernel, tokens_per_second, KernelRate};
use bitnet::threadpool::ThreadPool;
use bitnet::util::Json;

fn main() {
    let threads: usize = std::env::var("BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    let fast = std::env::var("BENCH_FAST").is_ok();
    let (m, k) = if fast { (2048, 4096) } else { (8192, 8192) };
    let pool = ThreadPool::new(threads);
    println!("# Table 7 reproduction — calibration shape {m}x{k}, {threads} threads");

    let kernels = QuantType::TABLE7;
    let mut rates: Vec<KernelRate> = Vec::new();
    for qt in kernels {
        let r = calibrate_kernel(qt, m, k, &pool, 3);
        println!(
            "# calibrated {:<6} {:>7.2} GB/s weight stream, {:>7.2} Gweight/s (bpw {:.2})",
            qt.name(),
            r.weight_bytes_per_s / 1e9,
            r.weights_per_s / 1e9,
            r.bpw
        );
        rates.push(r);
    }
    let f16_rate = rates.iter().find(|r| r.qtype == QuantType::F16).copied().unwrap();

    // Per-token non-GEMM overhead: measured on the tiny model elsewhere;
    // attention/norm cost scales ~ with hidden·ctx — small next to the
    // weight stream at these sizes. Use 2% of the I2_S stream time.
    println!("\n{:<6} {}", "size", kernels.map(|q| format!("{:>9}", q.name())).join(" "));
    let mut rows = Vec::new();
    for cfg in ModelConfig::table7_sizes() {
        let mut row = format!("{:<6}", cfg.name);
        let mut vals = Vec::new();
        for r in &rates {
            // Paper marks Float16 N/A where the dense model exceeds RAM
            // (30B+ on the 64 GB testbed).
            let dense_gb = cfg.param_count() as f64 * r.bpw / 8.0 / 1e9;
            if dense_gb > 60.0 {
                row.push_str(&format!("{:>10}", "N/A"));
                vals.push(None);
                continue;
            }
            let overhead = cfg.ternary_param_count() as f64 * 0.25
                / rates.last().unwrap().weight_bytes_per_s
                * 0.02;
            let tps = tokens_per_second(&cfg, r, &f16_rate, overhead);
            row.push_str(&format!("{:>10.2}", tps));
            vals.push(Some(tps));
        }
        println!("{row}");
        rows.push((cfg, vals));
    }

    // Figure 1 headline ratios on the largest size each pair supports.
    let idx = |q: QuantType| kernels.iter().position(|&x| x == q).unwrap();
    let (cfg, vals) = rows.last().unwrap();
    println!("\n# Figure 1 ({} model):", cfg.name);
    let pairs = [
        ("I2_S / Float16 (largest co-hosted size)", QuantType::I2S, QuantType::F16),
        ("TL2_0 / TMAC", QuantType::Tl20, QuantType::Tmac),
        ("TL2_0 / TQ1_0", QuantType::Tl20, QuantType::Tq10),
        ("TL2_0 / Q4_0", QuantType::Tl20, QuantType::Q40),
    ];
    for (label, a, b) in pairs {
        // Find the largest size where both are available.
        let row = rows
            .iter()
            .rev()
            .find(|(_, v)| v[idx(a)].is_some() && v[idx(b)].is_some());
        if let Some((cfg, v)) = row {
            let ratio = v[idx(a)].unwrap() / v[idx(b)].unwrap();
            println!("#   {label}: {ratio:.2}x @ {}", cfg.name);
        }
    }
    let _ = vals;

    // Machine-readable trajectory: one JSON document per run so CI can
    // archive the perf history (`BENCH_e2e.json` artifact).
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let rate_objs: Vec<Json> = rates
            .iter()
            .map(|r| {
                Json::Obj(vec![
                    ("kernel".into(), Json::Str(r.qtype.name().into())),
                    ("weight_gb_per_s".into(), Json::Num(r.weight_bytes_per_s / 1e9)),
                    ("gweights_per_s".into(), Json::Num(r.weights_per_s / 1e9)),
                    ("bpw".into(), Json::Num(r.bpw)),
                ])
            })
            .collect();
        let size_objs: Vec<Json> = rows
            .iter()
            .map(|(cfg, vals)| {
                let mut fields = vec![("size".to_string(), Json::Str(cfg.name.into()))];
                for (qt, v) in kernels.iter().zip(vals.iter()) {
                    let cell = match v {
                        Some(tps) => Json::Num(*tps),
                        None => Json::Null,
                    };
                    fields.push((qt.name().to_string(), cell));
                }
                Json::Obj(fields)
            })
            .collect();
        let doc = Json::Obj(vec![
            ("bench".into(), Json::Str("e2e_table7".into())),
            ("threads".into(), Json::Num(threads as f64)),
            ("fast".into(), Json::Bool(fast)),
            (
                "calibration_shape".into(),
                Json::Arr(vec![Json::Num(m as f64), Json::Num(k as f64)]),
            ),
            ("rates".into(), Json::Arr(rate_objs)),
            ("tokens_per_s".into(), Json::Arr(size_objs)),
        ]);
        std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_JSON");
        println!("# wrote {path}");
    }
}
