//! Raw kernel GEMV sweep: every kernel × a ladder of matmul shapes (the
//! per-projection shapes behind Table 7), timed at every SIMD tier the
//! kernel implements so the scalar→vector speedup is measured rather
//! than assumed. The generic profiling entry point for the §Perf
//! optimization loop.
//!
//! With `BENCH_JSON=path` set, the per-level rates merge into the shared
//! bench document under the `"kernel_sweep_simd"` key; other sections of
//! an existing file are preserved. (`e2e_table7` rewrites the whole
//! file, so it must run before the merging benches.)

use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::sparse::{self, SparseMode};
use bitnet::kernels::{kernel_for, simd, QuantType, SimdLevel};
use bitnet::perf::bench::{bench, black_box};
use bitnet::util::{Json, Rng};
use std::time::Duration;

/// Read-modify-write `BENCH_JSON`: replace `key` in the top-level object
/// (an unparsable or missing file starts a fresh document).
fn merge_into_bench_json(key: &str, value: Json) {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => p,
        Err(_) => return,
    };
    let mut pairs = match std::fs::read_to_string(&path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Obj(pairs)) => pairs,
        _ => Vec::new(),
    };
    pairs.retain(|(k, _)| k != key);
    pairs.push((key.to_string(), value));
    std::fs::write(&path, Json::Obj(pairs).to_string_pretty()).expect("write BENCH_JSON");
    println!("# wrote {path} ({key})");
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let shapes: &[(usize, usize)] =
        if fast { &[(1024, 1024)] } else { &[(1024, 1024), (4096, 4096), (8704, 3328)] };
    let levels = simd::available_levels();
    println!(
        "# kernel GEMV sweep (single thread; SIMD tiers: {})",
        levels.iter().map(|l| l.name()).collect::<Vec<_>>().join("/")
    );
    println!(
        "{:<9} {:>8} {:>8} {:>8} {:>12} {:>12} {:>10}",
        "kernel", "M", "K", "simd", "µs/GEMV", "Gweight/s", "vs scalar"
    );
    let mut records = Vec::new();
    for &(m, k) in shapes {
        let mut rng = Rng::new(3);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, m, k, 0.05);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let p = kern.prepare(&x, k);
            let mut out = vec![0f32; m];
            let mut scalar_mean = f64::NAN;
            for &level in &levels {
                if !kern.simd_levels().contains(&level) {
                    continue;
                }
                let r = simd::with_level(level, || {
                    bench(
                        kern.info().name,
                        Duration::from_millis(30),
                        Duration::from_millis(if fast { 100 } else { 250 }),
                        || {
                            kern.gemv(&packed, &p, &mut out);
                            black_box(&out);
                        },
                    )
                });
                let mean = r.seconds.mean;
                let speedup = if level == SimdLevel::Scalar {
                    scalar_mean = mean;
                    1.0
                } else {
                    scalar_mean / mean
                };
                println!(
                    "{:<9} {:>8} {:>8} {:>8} {:>12.1} {:>12.3} {:>9.2}x",
                    kern.info().name,
                    m,
                    k,
                    level.name(),
                    mean * 1e6,
                    (m * k) as f64 / mean / 1e9,
                    speedup
                );
                records.push(Json::Obj(vec![
                    ("kernel".into(), Json::Str(kern.info().name.into())),
                    ("m".into(), Json::Num(m as f64)),
                    ("k".into(), Json::Num(k as f64)),
                    ("simd".into(), Json::Str(level.name().into())),
                    ("us_per_gemv".into(), Json::Num(mean * 1e6)),
                    ("gweights_per_s".into(), Json::Num((m * k) as f64 / mean / 1e9)),
                    ("speedup_vs_scalar".into(), Json::Num(speedup)),
                ]));
            }
        }
    }
    merge_into_bench_json("kernel_sweep_simd", Json::Arr(records));

    // ── Sparse block-skip vs dense ─────────────────────────────────────
    // A 60%-zero-block tensor (384-column stripes, 3 of every 5 zeroed —
    // 384 is a common multiple of every sparse kernel's block span, so
    // the stripes elide for all of them), timed through both layouts at
    // scalar and the best vector tier. Results are bit-identical by
    // construction (tests/simd_identity.rs); this measures what the
    // elision *buys*.
    println!("\n# sparse block-skip vs dense (384-column zero stripes, 3 of 5 zeroed)");
    println!(
        "{:<9} {:>8} {:>8} {:>8} {:>12} {:>12} {:>10} {:>10}",
        "kernel", "M", "K", "simd", "dense µs", "sparse µs", "speedup", "zero-blk%"
    );
    let sparse_shapes: &[(usize, usize)] =
        if fast { &[(1024, 1920)] } else { &[(1024, 1920), (4096, 3840)] };
    let mut sparse_records = Vec::new();
    for &(m, k) in sparse_shapes {
        let mut rng = Rng::new(7);
        let q: Vec<i8> = (0..m * k)
            .map(|i| {
                let s = (i % k) / 384;
                if s * 3 % 5 < 3 {
                    0
                } else {
                    rng.next_ternary() as i8
                }
            })
            .collect();
        let t = TernaryWeights::from_ternary(q, m, k, 0.05);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        for qt in [QuantType::Tl11, QuantType::Tl21, QuantType::I2S, QuantType::Elut5] {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let dense = sparse::with_mode(SparseMode::Off, || kern.quantize(&t));
            let sp = sparse::with_mode(SparseMode::On, || kern.quantize(&t));
            let zero_frac = sp.sparse.as_ref().map_or(0.0, |i| i.zero_block_fraction());
            let p = kern.prepare(&x, k);
            let mut out = vec![0f32; m];
            for &level in &levels {
                if !kern.simd_levels().contains(&level) {
                    continue;
                }
                // Scalar + the best vector tier only: the middle tiers
                // add sweep time without changing the story.
                if level != SimdLevel::Scalar && Some(&level) != levels.last() {
                    continue;
                }
                let warm = Duration::from_millis(30);
                let dur = Duration::from_millis(if fast { 100 } else { 250 });
                let rd = simd::with_level(level, || {
                    bench(kern.info().name, warm, dur, || {
                        kern.gemv(&dense, &p, &mut out);
                        black_box(&out);
                    })
                });
                let rs = simd::with_level(level, || {
                    bench(kern.info().name, warm, dur, || {
                        kern.gemv(&sp, &p, &mut out);
                        black_box(&out);
                    })
                });
                let speedup = rd.seconds.mean / rs.seconds.mean;
                println!(
                    "{:<9} {:>8} {:>8} {:>8} {:>12.1} {:>12.1} {:>9.2}x {:>9.1}%",
                    kern.info().name,
                    m,
                    k,
                    level.name(),
                    rd.seconds.mean * 1e6,
                    rs.seconds.mean * 1e6,
                    speedup,
                    100.0 * zero_frac
                );
                sparse_records.push(Json::Obj(vec![
                    ("kernel".into(), Json::Str(kern.info().name.into())),
                    ("m".into(), Json::Num(m as f64)),
                    ("k".into(), Json::Num(k as f64)),
                    ("simd".into(), Json::Str(level.name().into())),
                    ("dense_us_per_gemv".into(), Json::Num(rd.seconds.mean * 1e6)),
                    ("sparse_us_per_gemv".into(), Json::Num(rs.seconds.mean * 1e6)),
                    ("sparse_speedup".into(), Json::Num(speedup)),
                    ("zero_block_fraction".into(), Json::Num(zero_frac)),
                ]));
            }
        }
    }
    merge_into_bench_json("sparsity", Json::Arr(sparse_records));
}
