//! Raw kernel GEMV sweep: every kernel × a ladder of matmul shapes (the
//! per-projection shapes behind Table 7). The generic profiling entry
//! point for the §Perf optimization loop.

use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, QuantType};
use bitnet::perf::bench::{bench, black_box};
use bitnet::util::Rng;
use std::time::Duration;

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let shapes: &[(usize, usize)] =
        if fast { &[(1024, 1024)] } else { &[(1024, 1024), (4096, 4096), (8704, 3328)] };
    println!("# kernel GEMV sweep (single thread)");
    println!("{:<9} {:>12} {:>12} {:>14} {:>12}", "kernel", "M", "K", "µs/GEMV", "Gweight/s");
    for &(m, k) in shapes {
        let mut rng = Rng::new(3);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, m, k, 0.05);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        for qt in QuantType::ALL {
            let kern = kernel_for(qt);
            if k % kern.info().k_multiple != 0 {
                continue;
            }
            let packed = kern.quantize(&t);
            let p = kern.prepare(&x, k);
            let mut out = vec![0f32; m];
            let r = bench(
                kern.info().name,
                Duration::from_millis(30),
                Duration::from_millis(if fast { 100 } else { 250 }),
                || {
                    kern.gemv(&packed, &p, &mut out);
                    black_box(&out);
                },
            );
            println!(
                "{:<9} {:>12} {:>12} {:>14.1} {:>12.3}",
                kern.info().name,
                m,
                k,
                r.seconds.mean * 1e6,
                (m * k) as f64 / r.seconds.mean / 1e9
            );
        }
    }
}
