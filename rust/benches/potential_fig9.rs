//! Paper Figure 9: ELUT performance-potential curve — estimated decode
//! tokens/s as memory bandwidth grows, for (a) MAD-based, (b) ELUT on
//! today's instructions, (c) ELUT with native hardware support
//! (TBL+ADD+CVT fused, the paper's C.2 estimate). Anchored on this
//! machine's measured bandwidth and compute rates.

use bitnet::kernels::QuantType;
use bitnet::model::ModelConfig;
use bitnet::perf::bandwidth::stream_read_gbps;
use bitnet::perf::calibrate::calibrate_kernel;
use bitnet::perf::roofline::CostModel;
use bitnet::threadpool::ThreadPool;

fn main() {
    let cfg = ModelConfig::b3_8();
    let pool = ThreadPool::new(std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(4));
    let fast = std::env::var("BENCH_FAST").is_ok();
    let (m, k) = if fast { (2048, 2048) } else { (8192, 8192) };

    // Anchor: measured compute throughput (weights/s at unlimited cache
    // bandwidth is approximated by the measured in-loop rate).
    let tl2 = calibrate_kernel(QuantType::Tl20, m, k, &pool, 2);
    let tq1 = calibrate_kernel(QuantType::Tq10, m, k, &pool, 2);
    let measured_bw = stream_read_gbps(&pool, if fast { 64 } else { 256 }, 3);

    let params = cfg.ternary_param_count() as f64;
    let head = (cfg.vocab_size * cfg.hidden) as f64;
    // ops/weight: ELUT ≈ 1/3 lookup+add; MAD ≈ 1 mul+add. Effective
    // compute ceilings derived from measured weights/s (these kernels are
    // near compute-bound single-socket at this working set).
    let elut_gops = tl2.weights_per_s / 1e9;
    let mad_gops = tq1.weights_per_s / 1e9;
    let mk = |bpw: f64, gweights: f64| CostModel {
        bytes_per_token: params * bpw / 8.0 + head * 2.0,
        ops_per_token: params / (gweights * 1e9) * 1e9, // 1 "op unit" per weight
        overhead_s: 0.0,
    };
    let elut = mk(1.67, elut_gops);
    let mad = mk(1.69, mad_gops);
    // Hardware-supported ELUT: the paper's C.2 — TBL+ADD+CVT fused would
    // recover the ~68% sequence overhead (Table 4), modeled as 1.68x
    // compute rate.
    let elut_hw = mk(1.67, elut_gops * 1.68);

    println!("# Figure 9 reproduction — {} model; measured anchor {measured_bw:.1} GB/s", cfg.name);
    println!("{:>10} {:>12} {:>12} {:>14}", "BW (GB/s)", "MAD tok/s", "ELUT tok/s", "ELUT+HW tok/s");
    for mult in [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0] {
        let bw = measured_bw * mult;
        println!(
            "{bw:>10.1} {:>12.2} {:>12.2} {:>14.2}",
            mad.tokens_per_second(bw, mad_gops),
            elut.tokens_per_second(bw, elut_gops),
            elut_hw.tokens_per_second(bw, elut_gops * 1.68),
        );
    }
    println!("# expected shape: all curves linear in BW until their compute knee;");
    println!("# ELUT's knee sits ~g× higher than MAD's; HW support raises it further.");
    println!(
        "# knees (GB/s): MAD {:.0}, ELUT {:.0}, ELUT+HW {:.0}",
        mad.memory_bound_knee_gbps(mad_gops),
        elut.memory_bound_knee_gbps(elut_gops),
        elut_hw.memory_bound_knee_gbps(elut_gops * 1.68),
    );
}
