//! Paper Figure 8: multi-threaded end-to-end performance, TL2_0 vs TQ1_0
//! (a: LUT vs MAD at equal bpw) and TL2_0 vs T-MAC (b: element-wise vs
//! bit-wise LUT) on the 3.8B model shapes.
//!
//! Env: BENCH_MAX_THREADS (default min(8, cores)), BENCH_FAST=1.

use bitnet::kernels::QuantType;
use bitnet::model::ModelConfig;
use bitnet::perf::calibrate::{calibrate_kernel, tokens_per_second};
use bitnet::threadpool::ThreadPool;

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let max_threads: usize = std::env::var("BENCH_MAX_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| cores.min(8));
    let fast = std::env::var("BENCH_FAST").is_ok();
    let cfg = ModelConfig::b3_8();
    let (m, k) = if fast { (2048, 3328) } else { (8704, 3328) }; // the 3.8B ffn shape
    println!("# Figure 8 reproduction — {} shapes, GEMV {m}x{k}, threads 1..{max_threads}", cfg.name);
    println!(
        "{:>7} {:>10} {:>10} {:>10}   (est. tokens/s on {})",
        "threads", "TL2_0", "TQ1_0", "TMAC", cfg.name
    );
    for t in 1..=max_threads {
        let pool = ThreadPool::new(t);
        let f16 = calibrate_kernel(QuantType::F16, m / 4, k, &pool, 2);
        let mut row = format!("{t:>7}");
        for qt in [QuantType::Tl20, QuantType::Tq10, QuantType::Tmac] {
            let r = calibrate_kernel(qt, m, k, &pool, 2);
            let tps = tokens_per_second(&cfg, &r, &f16, 0.0);
            row.push_str(&format!(" {tps:>10.2}"));
        }
        println!("{row}");
    }
    println!("# expected shape: TL2_0 > TQ1_0 at every thread count (a);");
    println!("# TL2_0 keeps scaling after TMAC saturates (b) — bpw 1.67 vs 2.0.");
}
