//! Paper Figure 8: multi-threaded end-to-end performance, TL2_0 vs TQ1_0
//! (a: LUT vs MAD at equal bpw) and TL2_0 vs T-MAC (b: element-wise vs
//! bit-wise LUT) on the 3.8B model shapes.
//!
//! The NUMA coda re-runs the heaviest thread count with the same workers
//! split across nodes (host topology when real, mock otherwise) and
//! reports the placed-dispatch counters; with `BENCH_JSON=path` set, it
//! merges into the shared bench document under the `"threads_fig8_numa"`
//! key without disturbing other sections (`e2e_table7` rewrites the
//! whole file, so it must run before the merging benches).
//!
//! Env: BENCH_MAX_THREADS (default min(8, cores)), BENCH_FAST=1,
//! BENCH_JSON=path.

use bitnet::kernels::QuantType;
use bitnet::model::ModelConfig;
use bitnet::perf::calibrate::{calibrate_kernel, tokens_per_second};
use bitnet::threadpool::ThreadPool;
use bitnet::topology::{NumaMode, Topology};
use bitnet::util::Json;

/// Read-modify-write `BENCH_JSON`: replace `key` in the top-level object
/// (an unparsable or missing file starts a fresh document).
fn merge_into_bench_json(key: &str, value: Json) {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => p,
        Err(_) => return,
    };
    let mut pairs = match std::fs::read_to_string(&path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Obj(pairs)) => pairs,
        _ => Vec::new(),
    };
    pairs.retain(|(k, _)| k != key);
    pairs.push((key.to_string(), value));
    std::fs::write(&path, Json::Obj(pairs).to_string_pretty()).expect("write BENCH_JSON");
    println!("# wrote {path} ({key})");
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let max_threads: usize = std::env::var("BENCH_MAX_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| cores.min(8));
    let fast = std::env::var("BENCH_FAST").is_ok();
    let cfg = ModelConfig::b3_8();
    let (m, k) = if fast { (2048, 3328) } else { (8704, 3328) }; // the 3.8B ffn shape
    println!("# Figure 8 reproduction — {} shapes, GEMV {m}x{k}, threads 1..{max_threads}", cfg.name);
    println!(
        "{:>7} {:>10} {:>10} {:>10}   (est. tokens/s on {})",
        "threads", "TL2_0", "TQ1_0", "TMAC", cfg.name
    );
    for t in 1..=max_threads {
        let pool = ThreadPool::new(t);
        let f16 = calibrate_kernel(QuantType::F16, m / 4, k, &pool, 2);
        let mut row = format!("{t:>7}");
        for qt in [QuantType::Tl20, QuantType::Tq10, QuantType::Tmac] {
            let r = calibrate_kernel(qt, m, k, &pool, 2);
            let tps = tokens_per_second(&cfg, &r, &f16, 0.0);
            row.push_str(&format!(" {tps:>10.2}"));
        }
        println!("{row}");
    }
    println!("# expected shape: TL2_0 > TQ1_0 at every thread count (a);");
    println!("# TL2_0 keeps scaling after TMAC saturates (b) — bpw 1.67 vs 2.0.");

    // NUMA coda: the heaviest thread count again, workers split across
    // nodes. Same GEMVs bit-for-bit — placement only changes which node
    // streams which row range, which the per-node chunk counters attest.
    let host = Topology::detect(NumaMode::Auto);
    let topo = if host.n_nodes() > 1 { host } else { Topology::mock(2) };
    let single = ThreadPool::new(max_threads);
    let placed = ThreadPool::with_topology(max_threads, topo);
    let f16_1 = calibrate_kernel(QuantType::F16, m / 4, k, &single, 2);
    let r_1 = calibrate_kernel(QuantType::Tl20, m, k, &single, 2);
    let tps_1 = tokens_per_second(&cfg, &r_1, &f16_1, 0.0);
    let f16_n = calibrate_kernel(QuantType::F16, m / 4, k, &placed, 2);
    let r_n = calibrate_kernel(QuantType::Tl20, m, k, &placed, 2);
    let tps_n = tokens_per_second(&cfg, &r_n, &f16_n, 0.0);
    let stats = placed.numa_stats();
    println!(
        "# NUMA ({} nodes{}, {max_threads} threads, TL2_0): {tps_1:.2} tok/s @ 1 node | {tps_n:.2} tok/s @ {} nodes",
        stats.nodes,
        if stats.mocked { " mocked" } else { "" },
        stats.nodes
    );
    println!(
        "#   per-node chunks {} | cross-node steals {}",
        stats.chunks.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("/"),
        stats.steals
    );
    merge_into_bench_json(
        "threads_fig8_numa",
        Json::Obj(vec![
            ("nodes".into(), Json::Num(stats.nodes as f64)),
            ("mocked".into(), Json::Bool(stats.mocked)),
            ("threads".into(), Json::Num(max_threads as f64)),
            ("kernel".into(), Json::Str(QuantType::Tl20.name().into())),
            ("tok_s_1node".into(), Json::Num(tps_1)),
            ("tok_s_nnodes".into(), Json::Num(tps_n)),
            (
                "per_node_chunks".into(),
                Json::Arr(stats.chunks.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("cross_node_steals".into(), Json::Num(stats.steals as f64)),
        ]),
    );
}
