//! Paper Table 4 + §C.2: core-instruction microbenchmarks — the LUT
//! path's 16-byte shuffle (vpshufb/vqtbl1q_u8 analogue) vs the MAD path's
//! multiply-add (maddubs analogue), and the full TBL+ADD+CVT sequence
//! whose extra latency motivates the hardware-support argument.

use bitnet::perf::bench::{bench_quick, black_box};
use bitnet::perf::simd::{add16, cvt_i8_i16, maddubs16, shuffle16, tbl_add_cvt};

const N: usize = 4096;

fn main() {
    let table: [i8; 16] = core::array::from_fn(|i| (i as i8) * 3 - 20);
    let idxs: Vec<[u8; 16]> = (0..N).map(|j| core::array::from_fn(|i| ((i * 7 + j) % 16) as u8)).collect();
    let a_u8: Vec<[u8; 16]> = (0..N).map(|j| core::array::from_fn(|i| ((i * 5 + j) % 250) as u8)).collect();
    let b_i8: Vec<[i8; 16]> = (0..N).map(|j| core::array::from_fn(|i| (((i * 11 + j) % 200) as i16 - 100) as i8)).collect();

    println!("# Table 4 reproduction — per-op latency of the core primitives");
    let r_tbl = bench_quick("TBL (shuffle16 only)", || {
        let mut acc = [0i8; 16];
        for idx in &idxs {
            let v = shuffle16(&table, idx);
            for i in 0..16 {
                acc[i] = acc[i].wrapping_add(v[i]);
            }
        }
        black_box(acc);
    });
    let r_mad = bench_quick("MAD (maddubs16)", || {
        let mut acc = [0i16; 8];
        for (a, b) in a_u8.iter().zip(&b_i8) {
            let v = maddubs16(a, b);
            acc = add16(&acc, &v);
        }
        black_box(acc);
    });
    let r_seq = bench_quick("TBL+ADD+CVT sequence", || {
        let mut acc = [0i16; 8];
        for idx in &idxs {
            acc = tbl_add_cvt(&table, idx, &acc);
        }
        black_box(acc);
    });
    let r_cvt = bench_quick("CVT alone", || {
        let mut acc = [0i16; 8];
        for (i, b) in b_i8.iter().enumerate() {
            let v = cvt_i8_i16(b);
            if i % 2 == 0 {
                acc = add16(&acc, &v);
            }
        }
        black_box(acc);
    });

    let per = |r: &bitnet::perf::BenchResult| r.seconds.mean / N as f64 * 1e9;
    println!("{:<24} {:>10}", "primitive", "ns/op");
    for r in [&r_tbl, &r_mad, &r_seq, &r_cvt] {
        println!("{:<24} {:>10.3}", r.name, per(r));
    }
    println!(
        "# paper: TBL ≈ MAD raw latency ({}x here); TBL+ADD+CVT ≈ 1.68x MAD ({:.2}x here)",
        format!("{:.2}", per(&r_tbl) / per(&r_mad)),
        per(&r_seq) / per(&r_mad)
    );
}
