//! Decode-attention sweep: the paged fused-attend hot loop (score dots +
//! weighted-V accumulation, f16 decoded inside the vector loops) timed
//! under the forced-scalar tier and under the host's vector tier
//! (AVX2/NEON), across KV dtype × context length. A second section
//! isolates what fusion buys on f16 pages: the fused decode-in-the-dot
//! path against the decode-to-scratch-then-dot baseline it replaced.
//!
//! Serial (no pool) on purpose — this measures the per-core SIMD win;
//! head-parallel scaling is `threads_fig8`'s department. With
//! `BENCH_JSON=path` set, results merge into the shared bench document
//! under the `"attention"` key. `BENCH_FAST=1` shortens runs (CI smoke).

use bitnet::coordinator::kv_pool::{AttnWorkspace, KvArena, KvDtype};
use bitnet::kernels::{simd, SimdLevel};
use bitnet::perf::bench::{bench, black_box};
use bitnet::simd::ops;
use bitnet::util::f16::f16_to_f32_fast;
use bitnet::util::{f32_to_f16, Json, Rng};
use std::time::Duration;

// A mid-size edge-model attention shape (GQA 4:1, 64-wide heads). One
// query row against `ctx` cached positions is exactly the per-layer
// decode-step workload.
const N_HEADS: usize = 16;
const N_KV_HEADS: usize = 4;
const HEAD_DIM: usize = 64;
const KV_DIM: usize = N_KV_HEADS * HEAD_DIM;

/// Read-modify-write `BENCH_JSON`: replace `key` in the top-level object
/// (an unparsable or missing file starts a fresh document).
fn merge_into_bench_json(key: &str, value: Json) {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => p,
        Err(_) => return,
    };
    let mut pairs = match std::fs::read_to_string(&path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Obj(pairs)) => pairs,
        _ => Vec::new(),
    };
    pairs.retain(|(k, _)| k != key);
    pairs.push((key.to_string(), value));
    std::fs::write(&path, Json::Obj(pairs).to_string_pretty()).expect("write BENCH_JSON");
    println!("# wrote {path} ({key})");
}

fn filled_arena(ctx: usize, dtype: KvDtype, rng: &mut Rng) -> KvArena {
    let mut arena = KvArena::with_page_tokens(1, KV_DIM, 4096, dtype, 16);
    assert!(arena.reserve(1, ctx));
    for pos in 0..ctx {
        let k: Vec<f32> = (0..KV_DIM).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f32> = (0..KV_DIM).map(|_| rng.next_gaussian()).collect();
        arena.append(1, 0, pos, &k, &v);
    }
    arena
}

/// µs per decode-attention call at a forced SIMD tier.
fn time_attend(arena: &KvArena, q: &[f32], ctx: usize, level: SimdLevel, fast: bool) -> f64 {
    let scale = 1.0 / (HEAD_DIM as f32).sqrt();
    let mut ws = AttnWorkspace::new();
    let mut out = vec![0f32; N_HEADS * HEAD_DIM];
    simd::with_level(level, || {
        bench(
            "attend",
            Duration::from_millis(20),
            Duration::from_millis(if fast { 80 } else { 250 }),
            || {
                out.fill(0.0);
                arena.attend_with(
                    &mut ws, 1, 0, q, ctx, N_HEADS, N_KV_HEADS, HEAD_DIM, scale, &mut out, None,
                );
                black_box(&out);
            },
        )
        .seconds
        .mean
            * 1e6
    })
}

fn sweep(fast: bool) -> Vec<Json> {
    let vector = simd::available_levels().into_iter().find(|&l| l != SimdLevel::Scalar);
    println!(
        "# decode attention ({N_HEADS}h/{N_KV_HEADS}kv, head_dim {HEAD_DIM}), forced scalar vs vector tier"
    );
    println!(
        "{:<6} {:>5} {:>12} {:>8} {:>12} {:>9}",
        "dtype", "ctx", "scalar µs", "tier", "vector µs", "speedup"
    );
    let mut records = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::F16] {
        for ctx in [64usize, 512, 2048] {
            let mut rng = Rng::new(17);
            let arena = filled_arena(ctx, dtype, &mut rng);
            let q: Vec<f32> = (0..N_HEADS * HEAD_DIM).map(|_| rng.next_gaussian()).collect();
            let scalar_us = time_attend(&arena, &q, ctx, SimdLevel::Scalar, fast);
            let (vec_cell, speedup_cell, tier_name) = match vector {
                Some(level) => {
                    let vec_us = time_attend(&arena, &q, ctx, level, fast);
                    (Json::Num(vec_us), Json::Num(scalar_us / vec_us), level.name())
                }
                None => (Json::Null, Json::Null, "-"),
            };
            let dt = format!("{dtype:?}");
            match (&vec_cell, &speedup_cell) {
                (Json::Num(v), Json::Num(s)) => println!(
                    "{dt:<6} {ctx:>5} {scalar_us:>12.1} {tier_name:>8} {v:>12.1} {s:>8.2}x"
                ),
                _ => println!(
                    "{dt:<6} {ctx:>5} {scalar_us:>12.1} {tier_name:>8} {:>12} {:>9}",
                    "-", "-"
                ),
            }
            records.push(Json::Obj(vec![
                ("dtype".into(), Json::Str(format!("{dtype:?}"))),
                ("ctx".into(), Json::Num(ctx as f64)),
                ("n_heads".into(), Json::Num(N_HEADS as f64)),
                ("n_kv_heads".into(), Json::Num(N_KV_HEADS as f64)),
                ("head_dim".into(), Json::Num(HEAD_DIM as f64)),
                ("scalar_us_per_step".into(), Json::Num(scalar_us)),
                ("vector_level".into(), Json::Str(tier_name.into())),
                ("vector_us_per_step".into(), vec_cell),
                ("speedup".into(), speedup_cell),
            ]));
        }
    }
    if vector.is_none() {
        println!("# (no vector tier on this host — scalar only)");
    }
    records
}

/// What fusing the f16 decode into the dot loop buys over the
/// decode-to-scratch baseline it replaced: `ctx` score dots of width
/// `HEAD_DIM` against f16 rows, fused vs materialize-then-dot, both at
/// the host's best tier.
fn fused_vs_scratch(fast: bool) -> Vec<Json> {
    let level = *simd::available_levels().last().expect("scalar tier always present");
    let mut rng = Rng::new(29);
    let q: Vec<f32> = (0..HEAD_DIM).map(|_| rng.next_gaussian()).collect();
    println!("\n# f16 score loop at {}: fused decode-in-dot vs decode-to-scratch", level.name());
    println!("{:>5} {:>12} {:>12} {:>9}", "ctx", "fused µs", "scratch µs", "speedup");
    let mut records = Vec::new();
    for ctx in [64usize, 512, 2048] {
        let rows: Vec<Vec<u16>> = (0..ctx)
            .map(|_| (0..HEAD_DIM).map(|_| f32_to_f16(rng.next_gaussian())).collect())
            .collect();
        let mut scores = vec![0f32; ctx];
        let budget = Duration::from_millis(if fast { 60 } else { 200 });
        let fused_us = simd::with_level(level, || {
            bench("fused", Duration::from_millis(10), budget, || {
                for (s, row) in scores.iter_mut().zip(&rows) {
                    *s = ops::dot_f16(&q, row);
                }
                black_box(&scores);
            })
            .seconds
            .mean
                * 1e6
        });
        let mut scratch = vec![0f32; HEAD_DIM];
        let scratch_us = simd::with_level(level, || {
            bench("scratch", Duration::from_millis(10), budget, || {
                for (s, row) in scores.iter_mut().zip(&rows) {
                    for (d, &h) in scratch.iter_mut().zip(row.iter()) {
                        *d = f16_to_f32_fast(h);
                    }
                    *s = ops::dot_f32(&q, &scratch);
                }
                black_box(&scores);
            })
            .seconds
            .mean
                * 1e6
        });
        println!(
            "{ctx:>5} {fused_us:>12.2} {scratch_us:>12.2} {:>8.2}x",
            scratch_us / fused_us
        );
        records.push(Json::Obj(vec![
            ("ctx".into(), Json::Num(ctx as f64)),
            ("level".into(), Json::Str(level.name().into())),
            ("fused_us".into(), Json::Num(fused_us)),
            ("scratch_us".into(), Json::Num(scratch_us)),
            ("speedup".into(), Json::Num(scratch_us / fused_us)),
        ]));
    }
    records
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let records = sweep(fast);
    let fusion = fused_vs_scratch(fast);
    merge_into_bench_json(
        "attention",
        Json::Obj(vec![
            ("sweep".into(), Json::Arr(records)),
            ("f16_fused_vs_scratch".into(), Json::Arr(fusion)),
        ]),
    );
}
