//! Paper Figure 10: token throughput and achieved memory bandwidth,
//! side-by-side, as threads scale (PCM stand-in: bandwidth derived from
//! bytes the kernel must stream / measured step time, plus a STREAM-style
//! ceiling measurement).

use bitnet::kernels::QuantType;
use bitnet::model::ModelConfig;
use bitnet::perf::bandwidth::stream_read_gbps;
use bitnet::perf::calibrate::{calibrate_kernel, tokens_per_second};
use bitnet::threadpool::ThreadPool;

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let max_threads: usize = std::env::var("BENCH_MAX_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| cores.min(8));
    let fast = std::env::var("BENCH_FAST").is_ok();
    let (m, k) = if fast { (2048, 2048) } else { (8192, 8192) };
    let cfg = ModelConfig::b700m(); // paper uses bitnet-b1.58-large (~700M)
    println!("# Figure 10 reproduction — I2_S on {} shapes", cfg.name);
    println!(
        "{:>7} {:>12} {:>16} {:>16}",
        "threads", "tokens/s", "achieved GB/s", "STREAM GB/s"
    );
    for t in 1..=max_threads {
        let pool = ThreadPool::new(t);
        let r = calibrate_kernel(QuantType::I2S, m, k, &pool, 2);
        let f16 = calibrate_kernel(QuantType::F16, m / 4, k, &pool, 2);
        let tps = tokens_per_second(&cfg, &r, &f16, 0.0);
        let stream = stream_read_gbps(&pool, if fast { 64 } else { 256 }, 3);
        println!(
            "{t:>7} {tps:>12.2} {:>16.2} {stream:>16.2}",
            r.weight_bytes_per_s / 1e9
        );
    }
    println!("# expected shape: tokens/s and achieved GB/s curves rise together and");
    println!("# flatten at the same thread count — throughput is bandwidth-limited.");
}
