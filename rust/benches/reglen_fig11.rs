//! Paper Figure 11: hypothetical SIMD register length vs raw lookup
//! latency. Emulates W-byte shuffles for W ∈ {16, 32, 64, 128} and
//! reports per-lookup latency plus the group size g each width enables
//! (C^g/2 entries ≤ W) and the resulting accumulation-complexity factor.

use bitnet::perf::bench::{bench_quick, black_box};
use bitnet::perf::simd::shuffle_w;

const N: usize = 4096;

fn run<const W: usize>() -> (usize, f64) {
    let tables: Vec<i8> = (0..W).map(|i| (i % 16) as i8 - 8).collect();
    let idxs: Vec<[u8; W]> = (0..N)
        .map(|j| core::array::from_fn(|i| ((i * 13 + j) % 16) as u8))
        .collect();
    let r = bench_quick(&format!("shuffle_w<{W}>"), || {
        let mut acc = 0i32;
        for idx in &idxs {
            let v = shuffle_w::<W>(&tables, idx);
            acc = acc.wrapping_add(v[0] as i32 + v[W - 1] as i32);
        }
        black_box(acc);
    });
    (W, r.seconds.mean / N as f64 * 1e9)
}

fn main() {
    println!("# Figure 11 reproduction — emulated register width vs lookup latency");
    println!(
        "{:>7} {:>12} {:>12} {:>6} {:>18}",
        "W bytes", "ns/lookup", "ns/byte", "max g", "accum ops ∝ 1/g"
    );
    let results = [run::<16>(), run::<32>(), run::<64>(), run::<128>()];
    for (w, ns) in results {
        // Largest g with ceil(3^g/2) ≤ 16·(w/16) table entries.
        let mut g = 1usize;
        while 3usize.pow((g + 1) as u32) / 2 + 1 <= w {
            g += 1;
        }
        println!(
            "{w:>7} {ns:>12.3} {:>12.4} {g:>6} {:>18.3}",
            ns / w as f64,
            1.0 / g as f64
        );
    }
    println!("# expected shape: ns/lookup grows sub-linearly with W while max g grows,");
    println!("# so wider registers reduce total accumulation work until C^g ≈ M (§C.3).");
}
