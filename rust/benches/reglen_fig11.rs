//! Paper Figure 11: hypothetical SIMD register length vs raw lookup
//! latency. Emulates W-byte shuffles for W ∈ {16, 32, 64, 128} and
//! reports per-lookup latency plus the group size g each width enables
//! (C^g/2 entries ≤ W) and the resulting accumulation-complexity factor.
//!
//! A second section measures the *real* TL LUT-gather hot loop — the
//! same GEMV timed under the forced-scalar tier and under the host's
//! vector tier (AVX2/NEON) — so the scalar→vector speedup in
//! BENCH_e2e.json is an observation, not an emulation. With
//! `BENCH_JSON=path` set, the measurement merges into the shared bench
//! document under the `"lut_gather_measured"` key.

use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, simd, QuantType, SimdLevel};
use bitnet::perf::bench::{bench, bench_quick, black_box};
use bitnet::perf::simd::shuffle_w;
use bitnet::util::{Json, Rng};
use std::time::Duration;

const N: usize = 4096;

fn run<const W: usize>() -> (usize, f64) {
    let tables: Vec<i8> = (0..W).map(|i| (i % 16) as i8 - 8).collect();
    let idxs: Vec<[u8; W]> = (0..N)
        .map(|j| core::array::from_fn(|i| ((i * 13 + j) % 16) as u8))
        .collect();
    let r = bench_quick(&format!("shuffle_w<{W}>"), || {
        let mut acc = 0i32;
        for idx in &idxs {
            let v = shuffle_w::<W>(&tables, idx);
            acc = acc.wrapping_add(v[0] as i32 + v[W - 1] as i32);
        }
        black_box(acc);
    });
    (W, r.seconds.mean / N as f64 * 1e9)
}

/// Read-modify-write `BENCH_JSON`: replace `key` in the top-level object
/// (an unparsable or missing file starts a fresh document).
fn merge_into_bench_json(key: &str, value: Json) {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => p,
        Err(_) => return,
    };
    let mut pairs = match std::fs::read_to_string(&path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Obj(pairs)) => pairs,
        _ => Vec::new(),
    };
    pairs.retain(|(k, _)| k != key);
    pairs.push((key.to_string(), value));
    std::fs::write(&path, Json::Obj(pairs).to_string_pretty()).expect("write BENCH_JSON");
    println!("# wrote {path} ({key})");
}

/// Time one kernel's GEMV at a forced SIMD tier (µs per GEMV).
fn time_gemv_at(
    kern: &'static dyn bitnet::kernels::Kernel,
    packed: &bitnet::kernels::QTensor,
    p: &bitnet::kernels::Prepared,
    out: &mut [f32],
    level: SimdLevel,
    fast: bool,
) -> f64 {
    simd::with_level(level, || {
        bench(
            kern.info().name,
            Duration::from_millis(20),
            Duration::from_millis(if fast { 80 } else { 200 }),
            || {
                kern.gemv(packed, p, out);
                black_box(&*out);
            },
        )
        .seconds
        .mean
            * 1e6
    })
}

fn measured_lut_gather(fast: bool) -> Vec<Json> {
    let (m, k) = (1024usize, 1024usize);
    let vector = simd::available_levels().into_iter().find(|&l| l != SimdLevel::Scalar);
    println!("\n# measured TL LUT gather: real GEMV, forced scalar vs vector tier (M=K=1024)");
    println!(
        "{:<9} {:>14} {:>8} {:>14} {:>10}",
        "kernel", "scalar µs", "tier", "vector µs", "speedup"
    );
    let mut records = Vec::new();
    for qt in [QuantType::Tl10, QuantType::Tl20, QuantType::Elut5] {
        let kern = kernel_for(qt);
        let mut rng = Rng::new(7);
        let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
        let t = TernaryWeights::from_ternary(q, m, k, 0.05);
        let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, k);
        let mut out = vec![0f32; m];
        let scalar_us = time_gemv_at(kern, &packed, &p, &mut out, SimdLevel::Scalar, fast);
        let vec_level = vector.filter(|l| kern.simd_levels().contains(l));
        let (vec_cell, speedup_cell, tier_name) = match vec_level {
            Some(level) => {
                let vec_us = time_gemv_at(kern, &packed, &p, &mut out, level, fast);
                (Json::Num(vec_us), Json::Num(scalar_us / vec_us), level.name())
            }
            None => (Json::Null, Json::Null, "-"),
        };
        match (&vec_cell, &speedup_cell) {
            (Json::Num(v), Json::Num(s)) => println!(
                "{:<9} {:>14.1} {:>8} {:>14.1} {:>9.2}x",
                kern.info().name,
                scalar_us,
                tier_name,
                v,
                s
            ),
            _ => println!(
                "{:<9} {:>14.1} {:>8} {:>14} {:>10}",
                kern.info().name,
                scalar_us,
                tier_name,
                "-",
                "-"
            ),
        }
        records.push(Json::Obj(vec![
            ("kernel".into(), Json::Str(kern.info().name.into())),
            ("m".into(), Json::Num(m as f64)),
            ("k".into(), Json::Num(k as f64)),
            ("scalar_us_per_gemv".into(), Json::Num(scalar_us)),
            ("vector_level".into(), Json::Str(tier_name.into())),
            ("vector_us_per_gemv".into(), vec_cell),
            ("speedup".into(), speedup_cell),
        ]));
    }
    if vector.is_none() {
        println!("# (no vector tier on this host — scalar only)");
    }
    records
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    println!("# Figure 11 reproduction — emulated register width vs lookup latency");
    println!(
        "{:>7} {:>12} {:>12} {:>6} {:>18}",
        "W bytes", "ns/lookup", "ns/byte", "max g", "accum ops ∝ 1/g"
    );
    let results = [run::<16>(), run::<32>(), run::<64>(), run::<128>()];
    for (w, ns) in results {
        // Largest g with ceil(3^g/2) ≤ 16·(w/16) table entries.
        let mut g = 1usize;
        while 3usize.pow((g + 1) as u32) / 2 + 1 <= w {
            g += 1;
        }
        println!(
            "{w:>7} {ns:>12.3} {:>12.4} {g:>6} {:>18.3}",
            ns / w as f64,
            1.0 / g as f64
        );
    }
    println!("# expected shape: ns/lookup grows sub-linearly with W while max g grows,");
    println!("# so wider registers reduce total accumulation work until C^g ≈ M (§C.3).");

    let records = measured_lut_gather(fast);
    merge_into_bench_json("lut_gather_measured", Json::Arr(records));
}
