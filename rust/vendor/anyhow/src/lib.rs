//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate, implementing exactly the subset this workspace uses:
//!
//! * [`Error`] — a context-chain error type (no backtraces, no downcasting);
//! * [`Result<T>`] with the `E = Error` default;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros;
//! * the [`Context`] extension trait for `Result` and `Option`.
//!
//! Display behaves like the real crate: `{}` shows the outermost message,
//! `{:#}` shows the whole chain joined with `": "`. The build image has no
//! registry access, hence a path dependency; swapping back to crates.io
//! `anyhow` requires no source changes.

use std::fmt;

/// A context-chain error. Outermost (most recently attached) message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message (what [`Context::context`] calls).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate, so `anyhow::Result<T>` and `Result<T, E>` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with an outer message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Coherent with the impl above for the same reason as the `From` blanket:
// `Error` provably does not implement `std::error::Error` in this crate.
impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.root_cause(), "file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            Err(anyhow!("inner"))
        }
        let e = inner().with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let e = inner().context("outer2").unwrap_err();
        assert_eq!(format!("{e}"), "outer2");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fallthrough {}", x))
        }
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fallthrough 1");
    }
}
