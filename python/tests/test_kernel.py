"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

The kernel must match the training-scheme integer reference BIT-FOR-BIT
(both produce integer-valued f32 accumulations rescaled identically) —
this is the Python-side half of the paper's lossless claim; the Rust side
asserts the same property for I2_S/TL1_1/TL2_1 (rust/tests/lossless.rs).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.ternary_matmul import lut_accumulate, ternary_matmul


def make_case(m, k, seed, scale=0.05):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, size=(m, k)).astype(np.float32)
    x = rng.normal(size=(k,)).astype(np.float32)
    return jnp.array(x), jnp.array(w), scale


@pytest.mark.parametrize("m,k", [(16, 48), (128, 768), (96, 300), (64, 256), (1, 3)])
def test_kernel_matches_integer_ref_exactly(m, k):
    x, w, s = make_case(m, k, seed=m * 1000 + k)
    out = np.array(ternary_matmul(x, w, s))
    want = np.array(ref.ternary_matmul_ref(x, w, s))
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("m,k", [(32, 192), (8, 96)])
def test_lut_decomposition_matches(m, k):
    x, w, s = make_case(m, k, seed=7)
    a = np.array(ref.lut_matmul_ref(x, w, s))
    b = np.array(ref.ternary_matmul_ref(x, w, s))
    np.testing.assert_array_equal(a, b)


def test_kernel_close_to_dense_float():
    x, w, s = make_case(64, 384, seed=9)
    out = np.array(ternary_matmul(x, w, s))
    dense = np.array(ref.dense_matmul_ref(x, w, s))
    norm = np.linalg.norm(dense) + 1e-9
    assert np.linalg.norm(out - dense) / norm < 0.02


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    kg=st.integers(1, 64),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_shape_sweep(m, kg, seed):
    """Hypothesis sweep over (M, K) shapes: exactness must hold for every
    geometry, including K not divisible by 3 (block-fit padding) and
    tile-boundary cases."""
    k = kg * 3 + (seed % 3)  # sometimes non-multiple of 3
    if k == 0:
        k = 3
    x, w, s = make_case(m, k, seed)
    out = np.array(ternary_matmul(x, w, s))
    want = np.array(ref.ternary_matmul_ref(x, w, s))
    np.testing.assert_array_equal(out, want)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-4, 10.0, allow_nan=False))
def test_weight_scale_linearity(scale):
    x, w, _ = make_case(16, 96, seed=3)
    a = np.array(ternary_matmul(x, w, scale))
    b = np.array(ternary_matmul(x, w, 1.0))
    np.testing.assert_allclose(a, b * scale, rtol=1e-5)


def test_dtype_promotion_bf16_activations():
    """bf16 activations are upcast and still go through the exact int path."""
    x, w, s = make_case(16, 48, seed=11)
    out16 = np.array(ternary_matmul(x.astype(jnp.bfloat16).astype(jnp.float32), w, s))
    assert out16.dtype == np.float32
    assert np.all(np.isfinite(out16))


def test_zero_activations_give_zero():
    _, w, s = make_case(8, 48, seed=12)
    out = np.array(ternary_matmul(jnp.zeros(48), w, s))
    np.testing.assert_array_equal(out, np.zeros(8))


def test_accumulator_direct():
    """Drive the Pallas kernel directly with a hand-built LUT."""
    kg, m = 4, 2
    lut = jnp.arange(kg * ref.HALF_TABLE, dtype=jnp.float32).reshape(kg, ref.HALF_TABLE)
    idx = jnp.array([[0, 1, 2, 3], [13, 12, 11, 10]], dtype=jnp.int32)
    sign = jnp.array([[1.0, 1.0, -1.0, 1.0], [1.0, -1.0, 1.0, -1.0]], dtype=jnp.float32)
    out = np.array(lut_accumulate(lut, idx, sign))
    expect = np.array([
        lut[0, 0] + lut[1, 1] - lut[2, 2] + lut[3, 3],
        lut[0, 13] - lut[1, 12] + lut[2, 11] - lut[3, 10],
    ])
    np.testing.assert_array_equal(out, expect)


def test_quantize_act_matches_rust_semantics():
    """Half-away rounding (Rust f32::round), clamp at +/-127."""
    x = jnp.array([1.0, -1.0, 0.5, -0.5, 0.0039370079, 127.5 / 127.0])
    xq, s = ref.quantize_act_int8(x)
    max_abs = 127.5 / 127.0
    assert np.isclose(float(s), 127.0 / max_abs)
    # 0.5 * s = 63.5 exactly? s = 127/ (127.5/127) = 126.5019... -> not a half case.
    assert np.all(np.abs(np.array(xq)) <= 127.0)
    # explicit half-away case
    xq2, _ = ref.quantize_act_int8(jnp.array([127.0, -0.5 / 127.0 * 127.0 * 0 + 1.0, 0.0]))
    assert float(xq2[0]) == 127.0
