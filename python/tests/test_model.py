"""L2 tests: BitNet block shapes, numerics, and KV/cache semantics."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model

H, F, T = 96, 192, 16
NH, NKV = 4, 2
KV = NKV * (H // NH)


def tern(rng, *shape):
    return jnp.array(rng.integers(-1, 2, size=shape).astype(np.float32))


@pytest.fixture
def weights():
    rng = np.random.default_rng(5)
    return dict(
        wq=tern(rng, H, H), wk=tern(rng, KV, H), wv=tern(rng, KV, H), wo=tern(rng, H, H),
        w_gate=tern(rng, F, H), w_up=tern(rng, F, H), w_down=tern(rng, H, F),
        attn_gain=jnp.ones(H), ffn_gain=jnp.ones(H),
        x=jnp.array(rng.normal(size=(H,)).astype(np.float32)),
    )


def run_block(w, k_cache, v_cache, pos):
    return model.bitnet_block(
        w["x"], k_cache, v_cache, jnp.int32(pos),
        w["wq"], w["wk"], w["wv"], w["wo"], w["w_gate"], w["w_up"], w["w_down"],
        0.08, w["attn_gain"], w["ffn_gain"], NH, NKV,
    )


def test_block_shapes_and_finiteness(weights):
    out, kn, vn = run_block(weights, jnp.zeros((T, KV)), jnp.zeros((T, KV)), 0)
    assert out.shape == (H,)
    assert kn.shape == (KV,) and vn.shape == (KV,)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_causality_future_cache_ignored(weights):
    """Rows beyond `pos` must not affect the output."""
    rng = np.random.default_rng(6)
    kc = jnp.array(rng.normal(size=(T, KV)).astype(np.float32))
    vc = jnp.array(rng.normal(size=(T, KV)).astype(np.float32))
    pos = 5
    out1, _, _ = run_block(weights, kc, vc, pos)
    # Scramble everything strictly after pos.
    kc2 = kc.at[pos + 1:].set(99.0)
    vc2 = vc.at[pos + 1:].set(-99.0)
    out2, _, _ = run_block(weights, kc2, vc2, pos)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))


def test_past_cache_does_matter(weights):
    rng = np.random.default_rng(7)
    kc = jnp.array(rng.normal(size=(T, KV)).astype(np.float32))
    vc = jnp.array(rng.normal(size=(T, KV)).astype(np.float32))
    out1, _, _ = run_block(weights, kc, vc, 5)
    vc2 = vc.at[2].set(7.0)
    out2, _, _ = run_block(weights, kc, vc2, 5)
    assert not np.array_equal(np.array(out1), np.array(out2))


def test_ffn_residual_passthrough(weights):
    """All-zero FFN weights reduce the FFN to identity (residual only)."""
    z = jnp.zeros_like
    out = model.bitnet_ffn(weights["x"], z(weights["w_gate"]), z(weights["w_up"]),
                           z(weights["w_down"]), 0.08, weights["ffn_gain"])
    np.testing.assert_array_equal(np.array(out), np.array(weights["x"]))


def test_rope_position_zero_identity():
    v = jnp.arange(KV, dtype=jnp.float32)
    out = model.rope_1tok(v, jnp.int32(0), NKV, H // NH)
    np.testing.assert_allclose(np.array(out), np.array(v), rtol=1e-6)


def test_rope_preserves_norm():
    v = jnp.arange(KV, dtype=jnp.float32)
    out = model.rope_1tok(v, jnp.int32(9), NKV, H // NH)
    assert np.isclose(float(jnp.linalg.norm(out)), float(jnp.linalg.norm(v)), rtol=1e-5)


def test_block_is_jit_stable(weights):
    """Same inputs, jitted twice -> same outputs (no trace-order effects)."""
    out1, _, _ = run_block(weights, jnp.zeros((T, KV)), jnp.zeros((T, KV)), 0)
    out2, _, _ = run_block(weights, jnp.zeros((T, KV)), jnp.zeros((T, KV)), 0)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))
