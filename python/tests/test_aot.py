"""AOT pipeline tests: HLO text is produced, parseable, and the manifest
matches the lowered input shapes."""

import os

from compile import aot


def test_hlo_text_generation():
    arts = aot.build_artifacts()
    assert [a[0] for a in arts] == ["ternary_matmul", "bitnet_ffn", "bitnet_block"]
    for name, lowered, shapes in arts:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # input count in the manifest matches the HLO entry params
        n_inputs = len([s for s in shapes.split(";") if s.strip()])
        assert text.count("parameter(") >= n_inputs, name


def test_artifacts_dir_contents():
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art_dir):
        import pytest
        pytest.skip("artifacts not built (run `make artifacts`)")
    for f in ["ternary_matmul.hlo.txt", "bitnet_ffn.hlo.txt", "bitnet_block.hlo.txt",
              "manifest.toml"]:
        assert os.path.exists(os.path.join(art_dir, f)), f
