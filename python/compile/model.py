"""Layer 2 — BitNet b1.58 building blocks in JAX, calling the Layer-1
Pallas kernel for every ternary projection. AOT-lowered by aot.py into the
HLO-text artifacts the Rust runtime executes (Python never runs on the
request path).

Functions are written decode-step style (single token, external KV) so the
lowered modules slot into the Rust coordinator's loop.
"""

import jax
import jax.numpy as jnp

from .kernels.ternary_matmul import ternary_matmul


def rmsnorm(x, gain, eps=1e-5):
    ss = jnp.mean(x * x)
    return x * jax.lax.rsqrt(ss + eps) * gain


def silu(x):
    return x / (1.0 + jnp.exp(-x))


def bitlinear(x, w, w_scale):
    """BitLinear: per-tensor int8 act quant + ternary matmul (Pallas)."""
    return ternary_matmul(x, w, w_scale)


def bitnet_ffn(x, w_gate, w_up, w_down, w_scale, gain):
    """SwiGLU FFN with ternary projections (one decode row).

    x: f32[H]; w_gate/w_up: f32[F,H]; w_down: f32[H,F]; gain: f32[H].
    """
    h = rmsnorm(x, gain)
    g = bitlinear(h, w_gate, w_scale)
    u = bitlinear(h, w_up, w_scale)
    return x + bitlinear(silu(g) * u, w_down, w_scale)


def rope_1tok(v, pos, n_heads, head_dim, theta=10000.0):
    """RoPE for a single token at (traced) integer position `pos`."""
    vh = v.reshape(n_heads, head_dim // 2, 2)
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    freq = 1.0 / theta ** (2.0 * i / head_dim)
    angle = pos.astype(jnp.float32) * freq  # (head_dim/2,)
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    a = vh[..., 0]
    b = vh[..., 1]
    out = jnp.stack([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return out.reshape(n_heads * head_dim)


def attention_decode(x, k_cache, v_cache, pos, wq, wk, wv, wo, w_scale, gain,
                     n_heads, n_kv_heads):
    """One attention decode step over a fixed-capacity cache.

    x: f32[H]; k_cache/v_cache: f32[T, KV]; pos: i32 scalar (tokens already
    in cache). Returns (y f32[H], k_new f32[KV], v_new f32[KV]) — the Rust
    coordinator owns the cache and writes k_new/v_new at row `pos`.
    """
    h = x.shape[0]
    t_cap, kv_dim = k_cache.shape
    head_dim = h // n_heads
    group = n_heads // n_kv_heads

    hn = rmsnorm(x, gain)
    q = rope_1tok(bitlinear(hn, wq, w_scale), pos, n_heads, head_dim)
    k_new = rope_1tok(bitlinear(hn, wk, w_scale), pos, n_kv_heads, head_dim)
    v_new = bitlinear(hn, wv, w_scale)

    # Attend over cache rows < pos plus the new row (causal decode).
    k_all = jax.lax.dynamic_update_slice(k_cache, k_new[None, :], (pos, 0))
    v_all = jax.lax.dynamic_update_slice(v_cache, v_new[None, :], (pos, 0))
    mask = jnp.arange(t_cap) <= pos  # (T,)

    qh = q.reshape(n_heads, head_dim)
    kh = k_all.reshape(t_cap, n_kv_heads, head_dim)
    vh = v_all.reshape(t_cap, n_kv_heads, head_dim)
    kv_head = jnp.arange(n_heads) // group
    scores = jnp.einsum("hd,thd->ht", qh, kh[:, kv_head, :]) / jnp.sqrt(float(head_dim))
    scores = jnp.where(mask[None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("ht,thd->hd", probs, vh[:, kv_head, :]).reshape(h)
    y = x + bitlinear(ctx, wo, w_scale)
    return y, k_new, v_new


def bitnet_block(x, k_cache, v_cache, pos, wq, wk, wv, wo, w_gate, w_up,
                 w_down, w_scale, attn_gain, ffn_gain, n_heads, n_kv_heads):
    """One full transformer block decode step (attention + FFN)."""
    y, k_new, v_new = attention_decode(
        x, k_cache, v_cache, pos, wq, wk, wv, wo, w_scale, attn_gain,
        n_heads, n_kv_heads,
    )
    out = bitnet_ffn(y, w_gate, w_up, w_down, w_scale, ffn_gain)
    return out, k_new, v_new
