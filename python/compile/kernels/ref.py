"""Pure-jnp oracle for the ternary mpGEMM kernel.

Reproduces the BitNet b1.58 training-scheme computation exactly (the
paper's "lossless" semantics, Figure 2):

* per-tensor int8 activation quantization, ``s = 127 / max|x|``;
* ternary weights with one per-tensor scale;
* integer accumulation, one combined rescale at the end.

Rounding note: Rust's ``f32::round`` is round-half-away-from-zero while
``jnp.round`` is round-half-to-even. The Rust L3 kernels are the reference
implementation, so this module (and therefore the AOT artifacts) uses
half-away rounding to stay bit-compatible across the language boundary.
"""

import jax.numpy as jnp

GROUP = 3  # element-wise group size g used by the TL2-style kernel
HALF_TABLE = 14  # mirror-consolidated table entries for C=3, g=3 (27//2+1)


def round_half_away(x):
    """Round half away from zero (Rust f32::round semantics)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def quantize_act_int8(x):
    """Per-tensor int8 activation quantization (BitNet b1.58 scheme).

    Returns (xq_as_f32, scale) with ``x ~= xq / scale``. Values stay in an
    f32 array (exact for |v| <= 127) so the artifact runs on any PJRT
    backend without int8 support.
    """
    max_abs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-5)
    scale = 127.0 / max_abs
    xq = jnp.clip(round_half_away(x * scale), -127.0, 127.0)
    return xq, scale


def ternary_matmul_ref(x, w, w_scale):
    """Reference mpGEMM: ``out[m] = sum_k x[k]*(w[m,k]*w_scale)`` through
    the training-scheme integer path.

    x: f32[K] raw activations; w: f32[M,K] ternary values in {-1,0,1};
    w_scale: python float or 0-d array. Returns f32[M].
    """
    xq, s = quantize_act_int8(x)
    acc = w @ xq  # integer values held in f32: |acc| <= K*127 < 2^24
    return acc * (w_scale / s)


def dense_matmul_ref(x, w, w_scale):
    """Loose float reference (no activation quantization)."""
    return (w * w_scale) @ x


# ---- TL2-style element-wise LUT decomposition (Phase 1 of Algorithm 2) ----

def _enumeration_matrix():
    """U[i, j]: weight value of digit j in positive-half code i (paper
    Table 6 order): code = mirror_join(0, i) over base-3 digits.

    Built from iota ops rather than a dense literal: the HLO-text printer
    elides array constants ("constant({...})"), which xla_extension
    0.5.1's parser silently reads as zeros — iota survives the text
    round-trip (see DESIGN.md #Substitutions).
    """
    mid = 13
    codes = jnp.arange(HALF_TABLE, dtype=jnp.int32) + mid  # (14,)
    power = jnp.array([9, 3, 1], dtype=jnp.int32)  # 3^(GROUP-1-j), tiny constant
    digits = (codes[:, None] // power[None, :]) % 3 - 1
    return digits.astype(jnp.float32)  # (14, 3)


ENUM_U = _enumeration_matrix()


def build_lut(xq):
    """Phase 1: enumerate the 14 positive-half group sums per activation
    group — on TPU this is a small MXU matmul, the vpshufb-table analogue
    (DESIGN.md section Hardware-Adaptation).

    xq: f32[K] quantized activations, K % 3 == 0.
    Returns f32[K/3, 14].
    """
    groups = xq.reshape(-1, GROUP)  # (K/3, 3)
    # Build the enumeration matrix inside the trace (as iota ops), not as
    # a captured constant: large dense literals are elided by the HLO-text
    # printer and read back as zeros by xla_extension 0.5.1.
    return groups @ _enumeration_matrix().T  # (K/3, 14)


def encode_weights(w):
    """Split ternary weights into (index, sign) planes — signed-unsigned
    weight splitting (paper Fig. 5).

    w: f32[M, K] ternary, K % 3 == 0.
    Returns idx i32[M, K/3] in [0, 14), sign f32[M, K/3] in {-1, +1}.
    """
    m, k = w.shape
    trios = w.reshape(m, k // GROUP, GROUP)
    code = ((trios[..., 0] + 1) * 9 + (trios[..., 1] + 1) * 3 + (trios[..., 2] + 1)).astype(
        jnp.int32
    )
    mid = 13
    sign = jnp.where(code >= mid, 1.0, -1.0).astype(jnp.float32)
    idx = jnp.abs(code - mid)
    return idx, sign


def lut_matmul_ref(x, w, w_scale):
    """The same training-scheme result computed through the LUT
    decomposition (pure jnp — the Pallas kernel must match this AND
    ternary_matmul_ref bit-for-bit)."""
    xq, s = quantize_act_int8(x)
    lut = build_lut(xq)  # (K/3, 14)
    idx, sign = encode_weights(w)  # (M, K/3)
    vals = jnp.take_along_axis(lut[None, :, :], idx[:, :, None], axis=2)[..., 0]
    acc = jnp.sum(sign * vals, axis=1)
    return acc * (w_scale / s)
