"""Layer 1 — the element-wise LUT ternary mpGEMM as a Pallas kernel.

This is the paper's TL2 accumulation phase (Algorithm 2, Phase 2)
re-thought for TPU (DESIGN.md section Hardware-Adaptation):

* the CPU version holds the 16-entry table in a SIMD register and indexes
  it with ``vpshufb``; here the mirror-consolidated table tile lives in
  VMEM and the "lookup" is a gather over the table's group axis;
* the 1-bit sign operation becomes a (+/-1) multiply fused into the
  accumulation;
* the BlockSpec grid expresses the HBM->VMEM streaming schedule the CPU
  code expressed with its LUT-centric block layout (Fig. 6): weights
  stream tile-by-tile, the LUT tile is reused across all M rows of the
  block, and partial sums accumulate into the output tile in VMEM.

Two lowering shapes:

* ``lut_accumulate_tiled`` — the production TPU shape: grid over
  (M, K/3) tiles with ``pl.when``-guarded output accumulation. Used by
  the pytest suite (interpret mode executes it faithfully).
* ``lut_accumulate`` — auto-tiles, and when a single tile covers the
  whole problem emits straight-line HLO (no grid while-loop, no
  conditional). The AOT artifacts use this shape: xla_extension 0.5.1
  (the Rust runtime's XLA) mis-executes the while/conditional pattern
  that jax 0.8's interpret-mode grid lowers to, producing zeros — see
  DESIGN.md #Substitutions.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot run (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default VMEM tile sizes for the tiled (TPU-shaped) path.
# bm*bkg*(4B idx + 4B sign) + bkg*14*4B LUT + bm*4B out stays well under
# ~16 MiB VMEM (see DESIGN.md #Perf).
BM = 1024
BKG = 1024


def _tile(n, cap):
    """Largest divisor of n that is <= cap (trace-time tile pick)."""
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def _kernel_tiled(lut_ref, idx_ref, sign_ref, o_ref):
    """One (BM x BKG) tile: gather + sign + accumulate into o_ref."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lut = lut_ref[...]          # (BKG, 14)
    idx = idx_ref[...]          # (BM, BKG)
    sign = sign_ref[...]        # (BM, BKG)
    # The vpshufb analogue: per-group table lookup as a gather along the
    # table axis, vectorized over the BM weight rows resident in VMEM.
    vals = jnp.take_along_axis(lut[None, :, :], idx[:, :, None], axis=2)[..., 0]
    o_ref[...] += jnp.sum(sign * vals, axis=1)


def _kernel_single(lut_ref, idx_ref, sign_ref, o_ref):
    """Whole problem in one VMEM tile: straight-line lowering."""
    lut = lut_ref[...]
    idx = idx_ref[...]
    sign = sign_ref[...]
    vals = jnp.take_along_axis(lut[None, :, :], idx[:, :, None], axis=2)[..., 0]
    o_ref[...] = jnp.sum(sign * vals, axis=1)


def lut_accumulate_tiled(lut, idx, sign, bm, bkg, interpret=True):
    """Grid-tiled Phase-2 accumulation (TPU production shape)."""
    m, kg = idx.shape
    assert m % bm == 0 and kg % bkg == 0, (m, kg, bm, bkg)
    grid = (m // bm, kg // bkg)
    return pl.pallas_call(
        _kernel_tiled,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bkg, ref.HALF_TABLE), lambda i, k: (k, 0)),
            pl.BlockSpec((bm, bkg), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bkg), lambda i, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(lut, idx, sign)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lut_accumulate(lut, idx, sign, interpret=True):
    """Phase-2 accumulation: returns f32[M] integer-valued sums.

    Single-tile problems lower to straight-line HLO (AOT-friendly);
    larger problems take the tiled grid path.
    """
    m, kg = idx.shape
    assert lut.shape[0] == kg and lut.shape[1] == ref.HALF_TABLE
    bm = _tile(m, BM)
    bkg = _tile(kg, BKG)
    if bm == m and bkg == kg:
        return pl.pallas_call(
            _kernel_single,
            out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
            interpret=interpret,
        )(lut, idx, sign)
    return lut_accumulate_tiled(lut, idx, sign, bm, bkg, interpret=interpret)


def ternary_matmul(x, w, w_scale, interpret=True):
    """Full mpGEMM through the Pallas kernel.

    Phase 1 (quantize + LUT build + weight encode) is plain jnp — it is
    O(K*C^g/g) work done once per activation row; Phase 2 (the O(M*K/g)
    hot loop) is the Pallas kernel. Matches ternary_matmul_ref bit-for-bit.
    """
    xq, s = ref.quantize_act_int8(x)
    # Block fitting, Python flavour: the Rust TL2 kernel splits the row
    # into a g=3 region plus a g=2 (TL1) tail to avoid padding-induced
    # latency; numerically, zero-padding K to a multiple of 3 is identical
    # (zero activations x zero weights contribute nothing), so the AOT
    # path pads — trace-time shapes only, no request-path cost.
    k = x.shape[0]
    pad = (-k) % ref.GROUP
    if pad:
        xq = jnp.pad(xq, (0, pad))
        w = jnp.pad(w, ((0, 0), (0, pad)))
    lut = ref.build_lut(xq)
    idx, sign = ref.encode_weights(w)
    acc = lut_accumulate(lut, idx, sign, interpret=interpret)
    return acc * (w_scale / s)
