"""AOT pipeline: lower the Layer-2 JAX functions (with the Layer-1 Pallas
kernel inlined, interpret=True) to **HLO text** and write the artifact
bundle the Rust runtime loads:

    artifacts/ternary_matmul.hlo.txt   the mpGEMM kernel alone
    artifacts/bitnet_ffn.hlo.txt       SwiGLU FFN decode row
    artifacts/bitnet_block.hlo.txt     full block decode step
    artifacts/manifest.toml            input shapes per artifact

HLO text (NOT HloModuleProto.serialize()) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Run via `make artifacts`:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Tiny-model geometry — must match ModelConfig::tiny() in rust/src/model/config.rs.
H, F, T = 256, 768, 64
N_HEADS, N_KV_HEADS = 4, 2
KV = N_KV_HEADS * (H // N_HEADS)
# Kernel-artifact geometry.
KM, KK = 256, 768


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def build_artifacts():
    """(name, lowered, input-shape spec) triples."""
    arts = []

    # 1. The mpGEMM kernel: out = ternary_matmul(x, w, w_scale).
    def matmul_fn(x, w):
        return (model.ternary_matmul(x, w, 0.05),)

    arts.append((
        "ternary_matmul",
        jax.jit(matmul_fn).lower(f32(KK), f32(KM, KK)),
        f"{KK};{KM}x{KK}",
    ))

    # 2. FFN decode row.
    def ffn_fn(x, w_gate, w_up, w_down, gain):
        return (model.bitnet_ffn(x, w_gate, w_up, w_down, 0.05, gain),)

    arts.append((
        "bitnet_ffn",
        jax.jit(ffn_fn).lower(f32(H), f32(F, H), f32(F, H), f32(H, F), f32(H)),
        f"{H};{F}x{H};{F}x{H};{H}x{F};{H}",
    ))

    # 3. Full block decode step.
    block = functools.partial(model.bitnet_block, n_heads=N_HEADS, n_kv_heads=N_KV_HEADS)

    def block_fn(x, k_cache, v_cache, pos, wq, wk, wv, wo, w_gate, w_up, w_down,
                 attn_gain, ffn_gain):
        return block(x, k_cache, v_cache, pos, wq, wk, wv, wo, w_gate, w_up,
                     w_down, 0.05, attn_gain, ffn_gain)

    arts.append((
        "bitnet_block",
        jax.jit(block_fn).lower(
            f32(H), f32(T, KV), f32(T, KV), i32(),
            f32(H, H), f32(KV, H), f32(KV, H), f32(H, H),
            f32(F, H), f32(F, H), f32(H, F), f32(H), f32(H),
        ),
        f"{H};{T}x{KV};{T}x{KV};1;{H}x{H};{KV}x{H};{KV}x{H};{H}x{H};{F}x{H};{F}x{H};{H}x{F};{H};{H}",
    ))
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    # kept for Makefile compatibility; --out names the primary artifact
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name, lowered, shapes in build_artifacts():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"[{name}]\ninputs = \"{shapes}\"\n")
        print(f"wrote {path} ({len(text)} chars)")
    # Legacy single-artifact name expected by the original Makefile target.
    if args.out:
        import shutil
        shutil.copy(os.path.join(out_dir, "bitnet_block.hlo.txt"), args.out)
    with open(os.path.join(out_dir, "manifest.toml"), "w") as f:
        f.write("\n".join(manifest))
    print(f"wrote {os.path.join(out_dir, 'manifest.toml')}")


if __name__ == "__main__":
    main()
