//! Direct (non-composed) Table 7 anchor: true end-to-end decode tokens/s
//! on sizes this host can materialize (tiny + 100M), all Table-7 kernels.
//! The composed full-ladder numbers come from `cargo bench e2e_table7`;
//! this example validates the composition against reality at small scale.
//!
//!     cargo run --offline --release --example table7 [threads]

use bitnet::kernels::QuantType;
use bitnet::model::{ModelConfig, Transformer};
use std::time::Instant;

fn main() {
    let threads: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    println!("# Table 7 (direct end-to-end anchor) — {threads} threads");
    println!("{:<7} {:<8} {:>12} {:>14}", "size", "kernel", "tok/s", "MB/token");
    for cfg in [ModelConfig::tiny(), ModelConfig::m100()] {
        let ck = bitnet::model::weights::Checkpoint::synthetic(&cfg, 1);
        for qt in QuantType::TABLE7 {
            let model = Transformer::from_checkpoint(&ck, qt, threads);
            let mut session = model.new_session(128);
            let mut logits = model.prefill(&mut session, &[1, 2, 3]);
            // Warm + measure decode steps.
            let n = if cfg.hidden > 512 { 12 } else { 48 };
            let t0 = Instant::now();
            for _ in 0..n {
                let tok = bitnet::model::sampling::argmax(&logits);
                logits = model.decode_step(&mut session, tok);
            }
            let tps = n as f64 / t0.elapsed().as_secs_f64();
            println!(
                "{:<7} {:<8} {:>12.2} {:>14.2}",
                cfg.name,
                qt.name(),
                tps,
                model.weight_bytes_per_token() as f64 / 1e6
            );
        }
    }
}
