//! Paper Table 1: the Bitnet.cpp ternary mpGEMM library — regenerated
//! from kernel metadata and *measured* packed storage (not constants).
//!
//!     cargo run --offline --release --example table1

use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, KernelClass, QuantType};
use bitnet::util::Rng;

fn main() {
    let (m, k) = (64, 3072);
    let mut rng = Rng::new(1);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let t = TernaryWeights::from_ternary(q, m, k, 0.05);

    println!("Table 1: Bitnet.cpp ternary mpGEMM library");
    println!("{:<9} {:<10} {:>14} {:>9}", "Kernel", "type", "bpw (measured)", "Lossless");
    for qt in [QuantType::Tl10, QuantType::Tl11, QuantType::Tl20, QuantType::Tl21, QuantType::I2S]
    {
        let kern = kernel_for(qt);
        let info = kern.info();
        let packed = kern.quantize(&t);
        println!(
            "{:<9} {:<10} {:>14.2} {:>9}",
            info.name,
            match info.class {
                KernelClass::LutBased => "LUT-based",
                KernelClass::MadBased => "MAD-based",
            },
            packed.bits_per_weight(),
            if info.lossless { "yes" } else { "no" }
        );
    }
}
