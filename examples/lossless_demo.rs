//! Paper Figure 2: lossless inference for BitNet b1.58.
//!
//! Quantizes one weight matrix + one activation vector exactly as BitNet
//! b1.58 training does, then runs every kernel in the library and prints
//! the deviation from the training-scheme result. Lossless kernels print
//! 0 (bit-identical); llama.cpp-style per-block kernels do not.
//!
//!     cargo run --offline --release --example lossless_demo

use bitnet::kernels::quant::{quantize_act_int8, training_scheme_ref_row, TernaryWeights};
use bitnet::kernels::{kernel_for, QuantType};
use bitnet::util::Rng;

fn main() {
    let (m, k) = (64, 1024);
    let mut rng = Rng::new(7);
    let q: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let t = TernaryWeights::from_ternary(q, m, k, 0.03125);
    // Block-heterogeneous activations — the case that separates per-tensor
    // from per-block quantization (paper §2.3).
    let mut x: Vec<f32> = (0..k).map(|_| rng.next_gaussian() * 0.1).collect();
    x[3] = 5.0;

    let act = quantize_act_int8(&x);
    let reference: Vec<f32> =
        (0..m).map(|r| training_scheme_ref_row(t.row(r), t.scale, &act)).collect();

    println!("{:<9} {:>12} {:>14}  note", "kernel", "max |Δ|", "rel L2 err");
    for qt in QuantType::ALL {
        let kern = kernel_for(qt);
        let info = kern.info();
        if k % info.k_multiple != 0 {
            continue;
        }
        let packed = kern.quantize(&t);
        let p = kern.prepare(&x, k);
        let mut out = vec![0f32; m];
        kern.gemv(&packed, &p, &mut out);
        let max_abs = out
            .iter()
            .zip(&reference)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        let err2: f64 = out.iter().zip(&reference).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let ref2: f64 = reference.iter().map(|v| (*v as f64).powi(2)).sum();
        let rel = (err2 / ref2).sqrt();
        println!(
            "{:<9} {:>12.3e} {:>14.3e}  {}",
            info.name,
            max_abs,
            rel,
            if max_abs == 0.0 { "LOSSLESS (bit-identical)" } else if info.lossless { "full-precision path differs from int path as expected" } else { "" }
        );
    }
}
