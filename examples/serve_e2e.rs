//! End-to-end serving driver (the DESIGN.md E2E validation run):
//! loads a ~100M-parameter BitNet b1.58 model (synthetic ternary weights,
//! real shapes), starts the continuous-batching engine, serves a batch of
//! requests, and reports latency/throughput — the serving-paper analogue
//! of a training-loss-curve run. Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --offline --release --example serve_e2e [threads] [kernel]

use bitnet::coordinator::{Engine, EngineConfig, Request};
use bitnet::kernels::QuantType;
use bitnet::model::{ModelConfig, SamplingParams, Transformer};
use bitnet::util::{Rng, Summary};
use std::sync::atomic::Ordering;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let threads: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let kernel = args
        .get(2)
        .and_then(|s| QuantType::parse(s))
        .unwrap_or(QuantType::Tl20);

    let cfg = ModelConfig::m100();
    eprintln!(
        "building {} model ({:.0}M params) with {} on {} threads…",
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        kernel.name(),
        threads
    );
    let t_build = std::time::Instant::now();
    let ck = bitnet::model::weights::Checkpoint::synthetic(&cfg, 42);
    let model = Transformer::from_checkpoint(&ck, kernel, threads);
    let wbytes = model.weight_bytes_per_token();
    eprintln!(
        "packed in {:.1}s; {:.1} MB streamed per decoded token",
        t_build.elapsed().as_secs_f64(),
        wbytes as f64 / 1e6
    );

    let engine = Engine::start(
        model,
        EngineConfig { max_batch: 8, kv_budget_tokens: 16384, eos_token: 1, seed: 0, ..Default::default() },
    );

    // Workload: 24 requests, prompts of 8–32 tokens, 24 new tokens each.
    let n_requests = 24;
    let mut rng = Rng::new(99);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|_| {
            let plen = 8 + rng.next_below(25);
            let prompt: Vec<u32> =
                (0..plen).map(|_| 3 + rng.next_below(cfg.vocab_size - 3) as u32).collect();
            engine.submit(Request {
                prompt,
                max_new_tokens: 24,
                sampling: SamplingParams::with_temperature(0.8),
                stop_on_eos: false,
            })
        })
        .collect();

    let mut ttfts = Vec::new();
    let mut tpss = Vec::new();
    let mut total_new = 0usize;
    for h in handles {
        let (tokens, _, stats) = h.wait();
        total_new += tokens.len();
        ttfts.push(stats.ttft.as_secs_f64() * 1e3);
        if stats.decode_tps() > 0.0 {
            tpss.push(stats.decode_tps());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let ttft = Summary::from_samples(&ttfts);
    let tps = Summary::from_samples(&tpss);
    println!("== serve_e2e ({} | {} threads) ==", kernel.name(), threads);
    println!("requests            {n_requests}");
    println!("generated tokens    {total_new}");
    println!("wall time           {wall:.2} s");
    println!("aggregate tok/s     {:.2}", total_new as f64 / wall);
    println!("per-seq decode tok/s mean {:.2} p50 {:.2}", tps.mean, tps.p50);
    println!("TTFT ms             mean {:.1} p50 {:.1} p99 {:.1}", ttft.mean, ttft.p50, ttft.p99);
    println!("engine              {}", engine.metrics.summary());
    println!(
        "achieved weight-stream bandwidth ≈ {:.2} GB/s",
        (total_new as f64 * wbytes as f64) / wall / 1e9
    );
    let steps = engine.metrics.decode_steps.load(Ordering::Relaxed);
    println!("decode steps        {steps} (mean batch {:.2})", engine.metrics.mean_batch());
}
