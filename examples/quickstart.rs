//! Quickstart: build a synthetic BitNet b1.58 model, run it under the
//! paper's lossless I2_S kernel, and generate a few tokens.
//!
//!     cargo run --offline --release --example quickstart

use bitnet::kernels::QuantType;
use bitnet::model::{sample, ModelConfig, SamplingParams, Transformer};
use bitnet::tokenizer::{synthetic_corpus, Tokenizer};
use bitnet::util::Rng;

fn main() {
    // 1. A model. Real deployments load a BTNZ checkpoint
    //    (bitnet::modelio::load); here we synthesize one.
    let cfg = ModelConfig::tiny();
    let model = Transformer::synthetic(&cfg, QuantType::I2S, 42);
    println!(
        "model {}: {:.1}M params, kernel {} ({} bpw packed)",
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        model.qtype.name(),
        model.layers[0].wq.qtensor.bits_per_weight(),
    );

    // 2. A prompt.
    let tok = Tokenizer::train(&synthetic_corpus(5000, 1), cfg.vocab_size);
    let prompt = tok.encode("the ternary model");

    // 3. Prefill + decode.
    let mut session = model.new_session(prompt.len() + 24);
    let mut logits = model.prefill(&mut session, &prompt);
    let mut rng = Rng::new(0);
    let params = SamplingParams::with_temperature(0.8);
    let mut out = Vec::new();
    for _ in 0..24 {
        let t = sample(&logits, &params, &mut rng);
        out.push(t);
        logits = model.decode_step(&mut session, t);
    }
    println!("generated: {:?}", tok.decode(&out));
}
