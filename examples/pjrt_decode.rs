//! Three-layer demo: execute the AOT-compiled JAX/Pallas artifacts
//! (built by `make artifacts`) from the Rust runtime and cross-check the
//! Pallas ternary kernel against the native Rust I2_S kernel.
//!
//!     make artifacts && cargo run --offline --release --example pjrt_decode

use bitnet::kernels::quant::TernaryWeights;
use bitnet::kernels::{kernel_for, QuantType};
use bitnet::runtime::{manifest_for, Runtime};
use bitnet::util::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let art_dir = Path::new("artifacts");
    if !art_dir.join("ternary_matmul.hlo.txt").exists() {
        eprintln!("artifacts/ not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());

    // 1. The Pallas mpGEMM kernel vs Rust I2_S on identical inputs.
    let exe = rt.load_hlo_text(&art_dir.join("ternary_matmul.hlo.txt"))?;
    let (m, k) = (256usize, 768usize);
    let mut rng = Rng::new(5);
    let wq: Vec<i8> = (0..m * k).map(|_| rng.next_ternary() as i8).collect();
    let w_f32: Vec<f32> = wq.iter().map(|&v| v as f32).collect();
    let x: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
    let t0 = std::time::Instant::now();
    let pjrt_out = &exe.execute_f32(&[(&x, &[k]), (&w_f32, &[m, k])])?[0];
    let pjrt_time = t0.elapsed();

    let t = TernaryWeights::from_ternary(wq, m, k, 0.05);
    let kern = kernel_for(QuantType::I2S);
    let packed = kern.quantize(&t);
    let p = kern.prepare(&x, k);
    let mut rust_out = vec![0f32; m];
    let t1 = std::time::Instant::now();
    kern.gemv(&packed, &p, &mut rust_out);
    let rust_time = t1.elapsed();

    let max_diff = pjrt_out
        .iter()
        .zip(&rust_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "ternary_matmul ({m}x{k}): pallas-via-PJRT vs Rust I2_S max |Δ| = {max_diff:.2e} \
         (PJRT {:.1}µs, Rust {:.1}µs)",
        pjrt_time.as_secs_f64() * 1e6,
        rust_time.as_secs_f64() * 1e6
    );

    // 2. Full transformer-block decode step artifact.
    let block = rt.load_hlo_text(&art_dir.join("bitnet_block.hlo.txt"))?;
    let entry = manifest_for(&art_dir.join("bitnet_block.hlo.txt")).expect("manifest");
    let t2 = std::time::Instant::now();
    let outs = block.execute_random(&entry)?;
    println!(
        "bitnet_block decode step: outputs (x', k_new, v_new) lens = {:?} in {:.1}µs",
        outs.iter().map(|o| o.len()).collect::<Vec<_>>(),
        t2.elapsed().as_secs_f64() * 1e6
    );
    println!("three-layer stack OK: Python built it, Rust runs it.");
    Ok(())
}
