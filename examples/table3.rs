//! Paper Table 3 (appendix): bit-wise vs element-wise bpw across weight
//! cardinalities — computed from the code-space math in kernels::lut.
//!
//!     cargo run --offline --release --example table3

use bitnet::kernels::lut::{bitwise_bpw, code_count, elementwise_bpw, half_code_count};

fn main() {
    println!("Table 3: bpw, bit-wise vs element-wise");
    println!("{:>3} {:>3} {:>8} {:>8}   note", "C", "g", "bpw_b", "bpw_e");
    for (c, g) in [(3usize, 3usize), (4, 2), (5, 2), (6, 2), (7, 2), (9, 2)] {
        let full = code_count(c, g);
        let mirrored = full > 16 && half_code_count(c, g) <= 16;
        println!(
            "{:>3} {:>3} {:>8.2} {:>8.2}   {}",
            c,
            g,
            bitwise_bpw(c),
            elementwise_bpw(c, g),
            if mirrored { "mirror consolidation" } else { "full enumeration" }
        );
    }
}
